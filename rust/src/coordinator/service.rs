//! The sampling service: a **sharded** coordinator — N partitions, each
//! owning its own bounded queue, condvar, and supervised worker sub-pool —
//! running solver loops with fault isolation around every execution.
//!
//! **Sharding.** A single queue mutex serializes admission, the batch
//! assembler's scan, and deadline shedding across every worker; at the
//! paper's <10-NFE operating point the per-request solver work is small
//! enough that this lock, not math, bounds throughput. The coordinator
//! therefore partitions into `ServerConfig::effective_shards()` shards.
//! Requests route at admission by `hash(batch_key) % shards`
//! ([`shard_for_key`]), so every member of a batchable cohort lands on the
//! same shard and batching/linger/deadline semantics below are per shard
//! and otherwise unchanged; solo (unplannable) jobs route round-robin.
//! Worker `i` homes on shard `i % shards` and, when its home queue is
//! empty, **steals** from the other shards so a skewed key distribution
//! cannot strand idle workers (`steals` metric, attributed to the shard
//! the job was stolen from). Metrics are per shard and merged on demand
//! ([`Metrics::merge`] — exact, raw-sample digest merge); the plan cache
//! stays global, so a config still compiles exactly once.
//!
//! Each worker pops a request and first tries the **batched plan path**:
//! requests whose batch key matches — the [`plan_key`] alone — are pulled
//! out of the queue into one lockstep run
//! ([`crate::solver::sample_batch_with_plan`]) that shares a cached
//! `Arc<SamplePlan>`, advances every member through the same timestep
//! grid, and evaluates the model backend **once per step** on the stacked
//! batch tensor. Model conditioning (class/guidance) is **not** part of
//! the key: the backend view is row-conditioned ([`CohortModel`]) — the
//! worker sorts members by conditioning before stacking, so each distinct
//! conditioning becomes one contiguous row range ([`CondSlab`]) evaluated
//! under its own class/guidance view, and a uniform cohort stays a single
//! slab on the whole-tensor fast path (zero cost over the pre-slab path).
//! Each worker keeps one pooled [`crate::solver::BatchWorkspace`] reused
//! across runs, so steady-state runs start without allocating. Batched
//! output is bit-identical to running each request alone — including
//! mixed-conditioning cohorts (`tests/batch_equiv.rs`), because every
//! kernel in the planned path and every backend slab eval is
//! row-independent. `ServerConfig::split_cond_batches` restores the legacy
//! conditioning-split keying as an ablation baseline.
//!
//! The batch assembler is bounded by `ServerConfig::max_batch` total rows
//! and, optionally, lingers `ServerConfig::batch_linger_us` for more
//! same-key arrivals (0 = coalesce only what is already queued) — never
//! past the earliest member deadline.
//!
//! **Fault tolerance.** Execution is wrapped in `catch_unwind`, so a panic
//! in a kernel or backend becomes a typed [`FailureKind::WorkerPanic`]
//! response for exactly the affected requests instead of a hung receiver.
//! A worker that caught a panic retires (its pooled workspace may be
//! corrupt); a supervisor guard respawns a replacement, keeping the pool
//! size invariant (`worker_restarts` counts this). A panic mid-batch
//! quarantines the cohort: every member is re-run solo (`batch_retries`),
//! so only the actual culprit fails and the rest stay bit-identical to a
//! fault-free run. Batched output is finiteness-checked per member on the
//! stacked tensor ([`Tensor::rows_finite`]); NaN/Inf rows fail only the
//! owning member ([`FailureKind::NonFiniteOutput`], `quarantined_members`)
//! because every kernel in the planned path is row-independent.
//!
//! **Deadlines.** Each request resolves a deadline at admission
//! (`deadline_ms`, defaulting to `ServerConfig::default_deadline_ms`; 0
//! disables). Jobs still queued past their deadline are shed at dequeue
//! with a typed [`FailureKind::DeadlineExceeded`] response and are never
//! executed.
//!
//! Every method in the registry compiles to a plan, so **the entire
//! workload is plan-cached and batchable** — UniPC, DPM-Solver++ (multistep
//! and singlestep), DPM-Solver, DEIS, PNDM, and DDIM requests all group by
//! batch key with no special-casing. The solo reference path only serves
//! requests whose method string fails admission parsing (to produce the
//! error response). With the PJRT backend, concurrent workers' model
//! evaluations additionally coalesce inside the runtime executor —
//! step-level dynamic batching below this layer.
//!
//! **Tracing.** Every request is minted a nonzero `trace_id` at admission
//! (or adopts a client-supplied one) and its lifecycle is recorded as
//! [`SpanEvent`]s — `admit`, `route`/`queue` at dequeue (steals attributed
//! to the victim shard), `assemble` for the batch gather, per-step
//! `model_eval`/`solver_step` pairs when `ServerConfig::trace` is `steps`,
//! and a terminal `respond` (or `quarantine`/`retry`) — into a per-shard
//! preallocated [`TraceRing`] sized by `ServerConfig::trace_buf`. Workers
//! stage events in a reusable scratch vec and flush under one lock per
//! batch, so steady-state recording touches neither the allocator nor a
//! global mutex (`tests/plan_alloc.rs` proves the former). A multi-member
//! batch additionally gets a **cohort** span: a fresh cohort id owns the
//! assemble/step spans and `cohort` link events tie each member to it.
//! [`Service::trace_json`] returns recent span trees and
//! [`Service::chrome_trace_json`] exports everything retained in Chrome
//! `trace_event` format. Independently of the span level, every completion
//! splits `compute` into exact `model_eval`/`solver` digests and feeds the
//! slowest-K exemplar store ([`Metrics`]).

use super::metrics::Metrics;
use super::request::{Conditioning, FailureKind, SampleRequest, SampleResponse};
use crate::analytic::GaussianMixture;
use crate::config::ServerConfig;
use crate::rng::Rng;
use crate::runtime::{PjrtHandle, PjrtModel};
use crate::sched::VpLinear;
use crate::solver::unipc::CoeffVariant;
use crate::solver::{
    plan_key, sample, sample_batch_with_plan_observed, BatchWorkspace, Model, Prediction,
    SampleOptions, SamplePlan,
};
use crate::telemetry::{
    BurnRateMonitor, EventHub, HealthAccum, HealthSpans, PromWriter, Subscription,
    TelemetryEvent, WindowTotals,
};
use crate::tensor::Tensor;
use crate::trace::{SpanEvent, Stage, StepSpans, TimedModel, TraceRing};
use std::any::Any;
use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// How long an idle worker sleeps on its home shard's condvar before
/// re-scanning every shard for stealable work. A submit only notifies the
/// *routed* shard's condvar, so this bounded wait is what lets an idle
/// worker discover a hot queue elsewhere; it also bounds shutdown-wakeup
/// latency.
const STEAL_POLL: Duration = Duration::from_micros(500);

/// How often the SLO monitor thread re-evaluates every configured
/// burn-rate objective against the windowed counters. Breach emission is
/// deduplicated per evaluation window, so a short tick costs only a few
/// windowed-totals sums, not alert spam.
const SLO_TICK: Duration = Duration::from_millis(100);

/// Fault-injection settings for [`ModelBackend::Chaos`]: a seeded,
/// deterministic fault stream drawn once per model evaluation. Each eval
/// independently draws a latency spike, a panic, and a NaN'd output row, in
/// that order, so a given seed produces the same fault schedule regardless
/// of which faults actually fire.
#[derive(Clone, Copy, Debug, Default)]
pub struct ChaosConfig {
    /// Seed for the fault stream (shared across all evals of this backend).
    pub seed: u64,
    /// Probability an eval panics (after any latency spike).
    pub panic_rate: f64,
    /// Probability an eval NaNs one row of its output.
    pub nan_rate: f64,
    /// Probability an eval sleeps `latency_us` first.
    pub latency_rate: f64,
    pub latency_us: u64,
    /// When set, only evaluations whose conditioning includes this class
    /// label draw faults; untargeted evaluations pass through untouched
    /// (and draw nothing from the fault stream). For a mixed-conditioning
    /// cohort the eval is targeted when **any** slab carries the class, and
    /// an injected NaN row is remapped into the targeted slabs' rows — so
    /// chaos aims at exactly the members conditioned on the class, which is
    /// how the mixed-cohort chaos tests prove per-member isolation. (The
    /// class no longer routes the request — the batch key is the plan key
    /// alone — so shard-isolation tests split shards by step count instead
    /// while still aiming faults by class.)
    pub target_class: Option<usize>,
}

/// What evaluates ε_θ for the service.
#[derive(Clone)]
pub enum ModelBackend {
    /// The learned model through the PJRT executor (production path).
    Pjrt(PjrtHandle),
    /// The analytic mixture (exact score; used for tests/benches and when
    /// no artifacts are available).
    Analytic {
        gm: Arc<GaussianMixture>,
        /// Component indices per class (classifier-free guidance support).
        class_components: Arc<Vec<Vec<usize>>>,
    },
    /// A fault-injecting decorator around another backend: panics, NaN
    /// rows, and latency spikes on a seeded deterministic schedule. Powers
    /// the chaos suite (`tests/fault_injection.rs`) and the serving bench's
    /// chaos ablation.
    Chaos {
        inner: Box<ModelBackend>,
        cfg: ChaosConfig,
        /// One shared fault stream: concurrent workers draw from the same
        /// seeded sequence, keeping the total fault mix at the configured
        /// rates regardless of interleaving.
        faults: Arc<Mutex<Rng>>,
    },
}

impl ModelBackend {
    pub fn dim(&self) -> usize {
        match self {
            ModelBackend::Pjrt(h) => h.dim,
            ModelBackend::Analytic { gm, .. } => gm.dim,
            ModelBackend::Chaos { inner, .. } => inner.dim(),
        }
    }

    /// Wrap a backend with seeded fault injection.
    pub fn chaos(inner: ModelBackend, cfg: ChaosConfig) -> ModelBackend {
        ModelBackend::Chaos {
            inner: Box::new(inner),
            faults: Arc::new(Mutex::new(Rng::seed_from(cfg.seed))),
            cfg,
        }
    }
}

/// Peel chaos decorators off a backend to reach the real evaluator.
fn base_backend(b: &ModelBackend) -> &ModelBackend {
    match b {
        ModelBackend::Chaos { inner, .. } => base_backend(inner),
        other => other,
    }
}

/// Install (once, process-wide) a panic hook that swallows the backtrace
/// noise of chaos-injected panics while delegating every real panic to the
/// previous hook. Call from chaos tests/benches before the first fault.
pub fn silence_injected_panics() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        let default = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let payload = info.payload();
            let msg = payload
                .downcast_ref::<&str>()
                .copied()
                .or_else(|| payload.downcast_ref::<String>().map(String::as_str));
            if !msg.is_some_and(|s| s.contains("chaos: injected")) {
                default(info);
            }
        }));
    });
}

/// A contiguous row range of a stacked batch whose rows share one model
/// conditioning — the unit at which [`CohortModel`] selects the backend
/// view. The worker sorts cohort members by conditioning before stacking,
/// so a cohort with k distinct conditionings coalesces to exactly k slabs,
/// and a uniform cohort to one (the whole-tensor fast path).
#[derive(Clone, Copy, Debug)]
pub struct CondSlab {
    /// First stacked row of the slab.
    pub start: usize,
    /// Number of rows (≥ 1 for slabs produced by [`CondSlab::coalesce`]).
    pub rows: usize,
    /// The model view these rows evaluate under.
    pub cond: Conditioning,
}

impl CondSlab {
    /// Coalesce per-member `(rows, conditioning)` pairs — in stacked row
    /// order — into maximal contiguous same-conditioning slabs
    /// (conditionings compared exactly, guidance by bits, via
    /// [`Conditioning::same`]).
    pub fn coalesce(members: impl IntoIterator<Item = (usize, Conditioning)>) -> Vec<CondSlab> {
        let mut slabs: Vec<CondSlab> = Vec::new();
        let mut start = 0usize;
        for (rows, cond) in members {
            match slabs.last_mut() {
                Some(s) if s.cond.same(&cond) => s.rows += rows,
                _ => slabs.push(CondSlab { start, rows, cond }),
            }
            start += rows;
        }
        slabs
    }
}

/// The row-conditioned model view over a [`ModelBackend`]: the stacked
/// batch is partitioned into [`CondSlab`]s and each slab's rows evaluate
/// under that slab's class/guidance. This is the backend contract that
/// lets mixed class/guidance requests share one lockstep batched run:
///
/// - A **uniform cohort is a single slab** and takes the whole-tensor fast
///   path — exactly the arithmetic of the pre-slab per-request view, so
///   the common case costs nothing.
/// - A **mixed cohort** evaluates per slab into one output tensor. Every
///   backend slab kernel is row-independent and hoists only
///   `(t, conditioning)`-dependent work, so each member's rows are
///   bit-identical to a solo run under its own conditioning
///   (`tests/batch_equiv.rs` proves this across the method zoo).
pub struct CohortModel<'a> {
    backend: &'a ModelBackend,
    sched: &'a VpLinear,
    slabs: Vec<CondSlab>,
    /// One PJRT adapter per slab (empty for non-PJRT backends): the
    /// executor below coalesces the per-slab calls back into padded device
    /// batches, so a mixed cohort still amortizes dispatch.
    pjrt: Vec<PjrtModel>,
}

impl<'a> CohortModel<'a> {
    /// A view over `slabs`, which must tile `[0, Σ rows)` contiguously in
    /// order (as produced by [`CondSlab::coalesce`]).
    pub fn new(backend: &'a ModelBackend, sched: &'a VpLinear, slabs: Vec<CondSlab>) -> Self {
        debug_assert!(!slabs.is_empty());
        debug_assert!(slabs.windows(2).all(|w| w[0].start + w[0].rows == w[1].start));
        debug_assert_eq!(slabs.first().map(|s| s.start), Some(0));
        let pjrt = match base_backend(backend) {
            ModelBackend::Pjrt(h) => slabs
                .iter()
                .map(|s| {
                    let mut m = PjrtModel::new(h.clone());
                    if let Some(c) = s.cond.class {
                        m = m.with_class(c, s.cond.guidance);
                    }
                    m
                })
                .collect(),
            _ => Vec::new(),
        };
        CohortModel { backend, sched, slabs, pjrt }
    }

    /// The single-slab view a solo request runs under (`rows` = its row
    /// count): the uniform fast path, bit-identical to the batched slab
    /// evaluation of the same rows.
    pub fn solo(
        backend: &'a ModelBackend,
        sched: &'a VpLinear,
        cond: Conditioning,
        rows: usize,
    ) -> Self {
        CohortModel::new(backend, sched, vec![CondSlab { start: 0, rows, cond }])
    }

    /// The slab partition this view evaluates under.
    pub fn slabs(&self) -> &[CondSlab] {
        &self.slabs
    }

    /// Whether a chaos config aims at this cohort: no target means every
    /// eval is targeted; with a target class, any slab conditioned on it
    /// makes the eval draw from the fault stream.
    fn chaos_targeted(&self, cfg: &ChaosConfig) -> bool {
        match cfg.target_class {
            None => true,
            Some(c) => self.slabs.iter().any(|s| s.cond.class == Some(c)),
        }
    }

    /// Rows belonging to slabs the chaos target aims at (all rows when
    /// untargeted), clipped to the actual output in case the eval tensor is
    /// smaller than the slab tiling (defensive; never happens in practice).
    fn chaos_target_rows(&self, cfg: &ChaosConfig, batch: usize) -> Vec<usize> {
        self.slabs
            .iter()
            .filter(|s| cfg.target_class.is_none() || s.cond.class == cfg.target_class)
            .flat_map(|s| s.start..s.start + s.rows)
            .filter(|&r| r < batch)
            .collect()
    }

    fn eval_backend(&self, backend: &ModelBackend, x: &Tensor, t: f64) -> Tensor {
        match backend {
            ModelBackend::Pjrt(_) => {
                if self.slabs.len() == 1 {
                    return self.pjrt[0].eval(x, t);
                }
                // Mixed cohort: one adapter call per slab; the runtime
                // executor coalesces compatible calls into padded device
                // batches below this layer.
                let mut out = Tensor::zeros(x.shape());
                for (slab, m) in self.slabs.iter().zip(&self.pjrt) {
                    let part = m.eval(&x.slice_rows(slab.start, slab.rows), t);
                    out.copy_rows_from(slab.start, &part);
                }
                out
            }
            ModelBackend::Analytic { gm, class_components } => {
                if let [slab] = self.slabs.as_slice() {
                    // Uniform fast path: whole-tensor eval + whole-tensor
                    // guidance combine, exactly the pre-slab arithmetic.
                    let subset = slab.cond.class.map(|c| class_components[c].as_slice());
                    let cond = gm.eps_star(self.sched, x, t, subset);
                    return match (slab.cond.guidance, subset) {
                        (Some(s), Some(_)) if s != 0.0 => {
                            let uncond = gm.eps_star(self.sched, x, t, None);
                            Tensor::lincomb(1.0 + s, &cond, -s, &uncond)
                        }
                        _ => cond,
                    };
                }
                let mut out = Tensor::zeros(x.shape());
                for slab in &self.slabs {
                    match (slab.cond.class, slab.cond.guidance) {
                        (Some(c), Some(s)) if s != 0.0 => gm.eps_star_guided_rows(
                            self.sched,
                            x,
                            t,
                            &class_components[c],
                            s,
                            slab.start,
                            slab.rows,
                            &mut out,
                        ),
                        (class, _) => gm.eps_star_rows(
                            self.sched,
                            x,
                            t,
                            class.map(|c| class_components[c].as_slice()),
                            slab.start,
                            slab.rows,
                            &mut out,
                        ),
                    }
                }
                out
            }
            ModelBackend::Chaos { inner, cfg, faults } => {
                if !self.chaos_targeted(cfg) {
                    // Untargeted conditioning: pass through without touching
                    // the fault stream, so targeted requests see the same
                    // fault schedule regardless of background traffic.
                    return self.eval_backend(inner, x, t);
                }
                // Draw the whole fault tuple in one lock scope — the same
                // number of draws per eval whether or not faults fire — and
                // release the lock before acting, so an injected panic can
                // never poison the shared fault stream.
                let (sleep, boom, nan_row) = {
                    let mut rng = faults.lock().unwrap();
                    let sleep = rng.uniform() < cfg.latency_rate;
                    let boom = rng.uniform() < cfg.panic_rate;
                    let nan = rng.uniform() < cfg.nan_rate;
                    let row = rng.below(x.batch().max(1));
                    (sleep, boom, nan.then_some(row))
                };
                if sleep {
                    std::thread::sleep(Duration::from_micros(cfg.latency_us));
                }
                if boom {
                    panic!("chaos: injected model panic");
                }
                let mut out = self.eval_backend(inner, x, t);
                if let Some(row) = nan_row {
                    // Remap the drawn row into the targeted slabs' rows so a
                    // class-aimed NaN always lands on a member conditioned
                    // on the target class. For untargeted configs (and
                    // uniform targeted cohorts) every row is eligible and
                    // the remap is the identity, preserving the pre-slab
                    // fault schedule bit-for-bit.
                    let eligible = self.chaos_target_rows(cfg, out.batch());
                    if !eligible.is_empty() {
                        for v in out.row_mut(eligible[row % eligible.len()]) {
                            *v = f64::NAN;
                        }
                    }
                }
                out
            }
        }
    }
}

impl Model for CohortModel<'_> {
    fn prediction(&self) -> Prediction {
        Prediction::Noise
    }

    fn eval(&self, x: &Tensor, t: f64) -> Tensor {
        self.eval_backend(self.backend, x, t)
    }

    fn dim(&self) -> usize {
        self.backend.dim()
    }
}

struct QueuedJob {
    req: SampleRequest,
    /// Fully-resolved solver options, derived once at admission (`None`
    /// only if the method string fails to parse, which admission already
    /// rejects — kept as an Option so the solo path can still produce the
    /// failure response).
    opts: Option<SampleOptions>,
    /// Batch key (the plan key alone; conditioning is carried per row by
    /// [`CohortModel`] instead), derived once at admission so the
    /// assembler's queue scan is an allocation-free string compare. `None`
    /// routes the job to the solo reference path.
    batch_key: Option<String>,
    reply: mpsc::Sender<SampleResponse>,
    enqueued: Instant,
    /// Absolute deadline resolved at admission; `None` = no deadline.
    deadline: Option<Instant>,
    /// Nonzero trace id minted (or adopted from the client) at admission;
    /// keys every span event this job produces and is echoed on the
    /// response.
    trace_id: u64,
}

/// Distinct solver configs are few in practice; the cap only guards against
/// a hostile client cycling order schedules to grow the map unboundedly.
const PLAN_CACHE_CAP: usize = 256;

/// Last-use LRU cache of compiled plans. A u64 logical clock stamps every
/// hit and insert; eviction removes the entry with the oldest stamp, so a
/// hot plan survives arbitrary churn of one-shot configs (the previous
/// arbitrary-eviction policy could dump the hottest plan).
struct PlanCache {
    cap: usize,
    clock: u64,
    map: HashMap<String, (Arc<SamplePlan>, u64)>,
}

impl PlanCache {
    fn new(cap: usize) -> PlanCache {
        PlanCache { cap: cap.max(1), clock: 0, map: HashMap::new() }
    }

    #[cfg(test)]
    fn len(&self) -> usize {
        self.map.len()
    }

    fn get(&mut self, key: &str) -> Option<Arc<SamplePlan>> {
        self.clock += 1;
        let clock = self.clock;
        self.map.get_mut(key).map(|e| {
            e.1 = clock;
            Arc::clone(&e.0)
        })
    }

    fn insert(&mut self, key: String, plan: Arc<SamplePlan>) {
        self.clock += 1;
        if self.map.len() >= self.cap && !self.map.contains_key(&key) {
            // O(n) scan is fine at this cap; eviction is rare by design.
            let victim = self.map.iter().min_by_key(|(_, v)| v.1).map(|(k, _)| k.clone());
            if let Some(k) = victim {
                self.map.remove(&k);
            }
        }
        self.map.insert(key, (plan, self.clock));
    }
}

/// One coordinator partition: a bounded queue, its condvar, and the metrics
/// store for traffic routed here. Workers home on a shard but steal from
/// the others when their own queue is dry.
struct Shard {
    /// This shard's index, so span events recorded by whoever holds a
    /// `&Shard` (owner or stealer) carry the owning partition.
    id: u32,
    queue: Mutex<VecDeque<QueuedJob>>,
    cv: Condvar,
    metrics: Mutex<Metrics>,
    /// Bounded span-event ring, preallocated at startup
    /// (`ServerConfig::trace_buf` slots): recording overwrites the oldest
    /// event and never allocates.
    trace: Mutex<TraceRing>,
}

impl Shard {
    fn new(id: u32, trace_cap: usize) -> Shard {
        Shard {
            id,
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            metrics: Mutex::new(Metrics::default()),
            trace: Mutex::new(TraceRing::new(trace_cap)),
        }
    }
}

/// The shard a batch key routes to: stable FNV-1a hash, so the same key —
/// and therefore every member of a batchable cohort — always lands on the
/// same shard for a given shard count.
pub fn shard_for_key(key: &str, shards: usize) -> usize {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in key.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    (h % shards.max(1) as u64) as usize
}

/// Pick the shard a submission lands on: batch-key hash for batchable
/// requests, round-robin for solo jobs (no key to hash; spreading them
/// keeps one pathological client from serializing a single shard).
fn route_shard(inner: &Inner, batch_key: Option<&str>) -> usize {
    match batch_key {
        Some(key) => shard_for_key(key, inner.shards.len()),
        None => inner.solo_rr.fetch_add(1, Ordering::Relaxed) % inner.shards.len(),
    }
}

struct Inner {
    shards: Vec<Shard>,
    cfg: ServerConfig,
    backend: ModelBackend,
    sched: VpLinear,
    /// Shared sampling plans keyed by [`plan_key`]: concurrent workers
    /// serving identically-configured requests execute from one
    /// `Arc<SamplePlan>` instead of re-deriving coefficients per request.
    /// Deliberately global (not per shard): a config compiles once no
    /// matter where its requests route or who steals them.
    plans: Mutex<PlanCache>,
    shutdown: AtomicBool,
    /// Round-robin cursor for solo (unplannable) jobs, which have no batch
    /// key to hash.
    solo_rr: AtomicUsize,
    /// Zero of the span-event clock: all `SpanEvent` timestamps are
    /// microseconds since this instant, so events from different shards
    /// (and the Chrome export) share one monotonic timeline.
    epoch: Instant,
    /// Trace-id mint. Starts at 1 — 0 is the "unset" sentinel on the wire.
    trace_ids: AtomicU64,
    /// Live worker handles tagged with each worker's home shard, joined by
    /// [`Service::shutdown`]. The supervisor pushes replacements here as it
    /// respawns panicked workers (same id ⇒ same home shard).
    handles: Mutex<Vec<(usize, JoinHandle<()>)>>,
    /// The push-based telemetry hub: spans and SLO breaches fan out to
    /// bounded per-subscriber queues at the same moment they are recorded
    /// into the trace rings, closing the ring-wrap blind spot. With no
    /// subscriber, every publish is one relaxed atomic load.
    hub: EventHub,
    /// The configured SLO burn-rate evaluators with their per-window
    /// dedup state; the monitor thread (and [`Service::poke_slos`]) drive
    /// it against the cross-shard windowed totals.
    monitor: Mutex<BurnRateMonitor>,
    /// Total `slo_breach` events emitted since boot.
    slo_breaches: AtomicU64,
    /// SLO monitor thread handle, joined at shutdown.
    monitor_handle: Mutex<Option<JoinHandle<()>>>,
}

impl Inner {
    /// `at` on the span-event clock: microseconds since the service epoch
    /// (0 for an instant that somehow predates it).
    fn rel_us(&self, at: Instant) -> u64 {
        at.checked_duration_since(self.epoch).map_or(0, |d| d.as_micros() as u64)
    }

    /// Now on the windowed-metrics clock: whole seconds since the service
    /// epoch (the slot key for [`crate::telemetry::WindowStore`]).
    fn now_s(&self) -> u64 {
        self.epoch.elapsed().as_secs()
    }

    /// Mint a fresh nonzero trace id.
    fn mint_trace_id(&self) -> u64 {
        self.trace_ids.fetch_add(1, Ordering::Relaxed)
    }

    /// Record one span into `shard`'s ring and publish it to subscribers.
    /// Every ring record site routes through here (or
    /// [`Inner::record_spans`]) so the push channel sees exactly what the
    /// ring sees.
    fn record_span(&self, shard: &Shard, ev: SpanEvent) {
        shard.trace.lock().unwrap().record(ev);
        self.hub.publish(TelemetryEvent::Span(ev));
    }

    /// Flush a batch of spans into `shard`'s ring and publish them: one
    /// ring lock and one queue lock per subscriber for the whole batch.
    fn record_spans(&self, shard: &Shard, evs: &[SpanEvent]) {
        shard.trace.lock().unwrap().record_all(evs);
        self.hub.publish_spans(evs);
    }

    /// Cross-shard windowed totals for the trailing `window_s` seconds.
    fn window_totals(&self, now_s: u64, window_s: u64) -> WindowTotals {
        let mut t = WindowTotals { window_s, ..WindowTotals::default() };
        for shard in &self.shards {
            let m = shard.metrics.lock().unwrap();
            t.add_totals(&m.windows.totals(now_s, window_s));
        }
        t
    }

    /// Evaluate every configured SLO once at `now_s`; emits breach events
    /// on the push channel and counts them. Returns how many fired.
    fn evaluate_slos(&self, now_s: u64) -> usize {
        let mut events = Vec::new();
        {
            let mut mon = self.monitor.lock().unwrap();
            mon.evaluate(now_s, |w| self.window_totals(now_s, w), &mut events);
        }
        for ev in &events {
            self.slo_breaches.fetch_add(1, Ordering::Relaxed);
            self.hub.publish(*ev);
        }
        events.len()
    }
}

/// The SLO monitor loop: tick until shutdown. Kept out of the worker pool —
/// burn evaluation must not compete with sampling for a queue slot.
fn monitor_loop(inner: Arc<Inner>) {
    while !inner.shutdown.load(Ordering::SeqCst) {
        std::thread::sleep(SLO_TICK);
        inner.evaluate_slos(inner.now_s());
    }
}

/// The running service (clone to share).
#[derive(Clone)]
pub struct Service {
    inner: Arc<Inner>,
}

impl Service {
    /// Start the sharded worker pool: `cfg.effective_shards()` shards, with
    /// worker `i` homed on shard `i % shards`.
    pub fn start(cfg: ServerConfig, backend: ModelBackend) -> Service {
        let n_shards = cfg.effective_shards();
        let trace_cap = cfg.trace_buf;
        let slos = cfg.slos.clone();
        let inner = Arc::new(Inner {
            shards: (0..n_shards).map(|i| Shard::new(i as u32, trace_cap)).collect(),
            cfg,
            backend,
            sched: VpLinear::default(),
            plans: Mutex::new(PlanCache::new(PLAN_CACHE_CAP)),
            shutdown: AtomicBool::new(false),
            solo_rr: AtomicUsize::new(0),
            epoch: Instant::now(),
            trace_ids: AtomicU64::new(1),
            handles: Mutex::new(Vec::new()),
            hub: EventHub::new(),
            monitor: Mutex::new(BurnRateMonitor::new(slos.clone())),
            slo_breaches: AtomicU64::new(0),
            monitor_handle: Mutex::new(None),
        });
        for i in 0..inner.cfg.workers {
            spawn_worker(&inner, i);
        }
        if !slos.is_empty() {
            let arc = Arc::clone(&inner);
            let handle = std::thread::Builder::new()
                .name("slo-monitor".into())
                .spawn(move || monitor_loop(arc))
                .expect("spawn slo monitor");
            *inner.monitor_handle.lock().unwrap() = Some(handle);
        }
        Service { inner }
    }

    /// Submit a request. Routes to a shard at admission — by batch-key hash
    /// for batchable requests (so a cohort always lands together), round-
    /// robin for solo jobs — and applies admission control: invalid
    /// requests, a full shard queue (backpressure), and a shut-down service
    /// are rejected immediately with the typed response they would
    /// otherwise have received on the channel. All admission bookkeeping
    /// lands on the routed shard's metrics.
    pub fn submit(
        &self,
        req: SampleRequest,
    ) -> Result<mpsc::Receiver<SampleResponse>, SampleResponse> {
        let arrived = Instant::now();
        // Adopt a client-supplied nonzero trace id, else mint one; rejected
        // requests carry it too so a client can correlate the refusal.
        let trace_id = match req.trace_id {
            Some(t) if t != 0 => t,
            _ => self.inner.mint_trace_id(),
        };
        let stamp = |mut resp: SampleResponse| {
            resp.trace_id = trace_id;
            resp
        };
        let (opts, batch_key) = admission_setup(&self.inner, &req);
        let shard = &self.inner.shards[route_shard(&self.inner, batch_key.as_deref())];
        let now_s = self.inner.now_s();
        {
            let mut metrics = shard.metrics.lock().unwrap();
            metrics.submitted += 1;
            // Rejections bump `rejected` + the per-kind counter (not the
            // cumulative `failed`, which counts accepted-then-failed jobs)
            // but DO land in the windowed failure slots: SLOs over
            // queue_full / invalid_request need them visible in rates.
            if self.inner.shutdown.load(Ordering::SeqCst) {
                metrics.rejected += 1;
                metrics.failures_by_kind[FailureKind::BackendError.index()] += 1;
                metrics.windows.record_failure(now_s, FailureKind::BackendError);
                return Err(stamp(SampleResponse::failure(
                    FailureKind::BackendError,
                    "service is shut down".into(),
                )));
            }
            if let Err(e) = req.validate(self.inner.cfg.max_batch) {
                metrics.rejected += 1;
                metrics.failures_by_kind[FailureKind::InvalidRequest.index()] += 1;
                metrics.windows.record_failure(now_s, FailureKind::InvalidRequest);
                return Err(stamp(SampleResponse::failure(
                    FailureKind::InvalidRequest,
                    format!("{e:#}"),
                )));
            }
        }

        let (tx, rx) = mpsc::channel();
        let (n, steps) = (req.n, req.steps);
        let enqueued = Instant::now();
        let deadline = resolve_deadline_ms(&self.inner.cfg, &req)
            .map(|ms| enqueued + Duration::from_millis(ms));
        let depth = {
            let mut q = shard.queue.lock().unwrap();
            if q.len() >= self.inner.cfg.queue_cap {
                let pending = q.len();
                drop(q);
                let mut metrics = shard.metrics.lock().unwrap();
                metrics.rejected += 1;
                metrics.failures_by_kind[FailureKind::QueueFull.index()] += 1;
                metrics.windows.record_failure(now_s, FailureKind::QueueFull);
                return Err(stamp(SampleResponse::failure(
                    FailureKind::QueueFull,
                    format!("queue full ({pending} pending)"),
                )));
            }
            q.push_back(QueuedJob {
                req,
                opts,
                batch_key,
                reply: tx,
                enqueued,
                deadline,
                trace_id,
            });
            q.len()
        };
        shard.metrics.lock().unwrap().record_depth(now_s, depth);
        if self.inner.cfg.trace.lifecycle() {
            self.inner.record_span(
                shard,
                SpanEvent {
                    trace_id,
                    parent: 0,
                    stage: Stage::Admit,
                    shard: shard.id,
                    start_us: self.inner.rel_us(arrived),
                    dur_us: arrived.elapsed().as_micros() as u64,
                    a: n as u64,
                    b: steps as u64,
                },
            );
        }
        // notify_all, not notify_one: a lingering batch assembler waits on
        // this same condvar and would otherwise swallow the only wakeup
        // meant for an idle worker, stranding a non-matching job for the
        // rest of the linger window.
        shard.cv.notify_all();
        Ok(rx)
    }

    /// Submit and wait for the result. The wait itself is bounded by the
    /// request deadline (plus a grace window for a job admitted just inside
    /// its deadline to finish computing), so a stuck worker can't hang the
    /// caller.
    pub fn sample_blocking(&self, req: SampleRequest) -> SampleResponse {
        let deadline_ms = resolve_deadline_ms(&self.inner.cfg, &req);
        let rx = match self.submit(req) {
            Ok(rx) => rx,
            Err(resp) => return resp,
        };
        match deadline_ms {
            None => rx.recv().unwrap_or_else(|_| {
                SampleResponse::failure(FailureKind::WorkerPanic, "worker dropped request".into())
            }),
            Some(ms) => {
                let grace = Duration::from_millis(self.inner.cfg.drain_deadline_ms.max(1_000));
                match rx.recv_timeout(Duration::from_millis(ms) + grace) {
                    Ok(resp) => resp,
                    Err(mpsc::RecvTimeoutError::Timeout) => SampleResponse::failure(
                        FailureKind::DeadlineExceeded,
                        format!("no response within deadline ({ms} ms + grace)"),
                    ),
                    Err(mpsc::RecvTimeoutError::Disconnected) => SampleResponse::failure(
                        FailureKind::WorkerPanic,
                        "worker dropped request".into(),
                    ),
                }
            }
        }
    }

    /// The global snapshot: every shard's metrics merged exactly
    /// ([`Metrics::merge`] — counters/histograms sum, digests merge raw
    /// samples so percentiles stay exact), plus the shard-level gauges
    /// `shards` (partition count) and `shard_depths` (current queue depth
    /// per shard, in shard order).
    pub fn metrics_json(&self) -> crate::json::Value {
        let mut agg = Metrics::default();
        for shard in &self.inner.shards {
            agg.merge(&shard.metrics.lock().unwrap());
        }
        let mut v = agg.snapshot_json();
        if let crate::json::Value::Obj(m) = &mut v {
            m.insert(
                "shards".into(),
                crate::json::Value::Num(self.inner.shards.len() as f64),
            );
            m.insert(
                "shard_depths".into(),
                crate::json::Value::Arr(
                    self.inner
                        .shards
                        .iter()
                        .map(|s| crate::json::Value::Num(s.queue.lock().unwrap().len() as f64))
                        .collect(),
                ),
            );
            let (mut recorded, mut dropped) = (0u64, 0u64);
            for s in &self.inner.shards {
                let tr = s.trace.lock().unwrap();
                recorded += tr.recorded();
                dropped += tr.dropped();
            }
            m.insert("trace_recorded".into(), crate::json::Value::Num(recorded as f64));
            m.insert("trace_dropped".into(), crate::json::Value::Num(dropped as f64));
            m.insert(
                "sub_dropped".into(),
                crate::json::Value::Num(self.inner.hub.dropped() as f64),
            );
            m.insert(
                "subscribers".into(),
                crate::json::Value::Num(self.inner.hub.active() as f64),
            );
            m.insert(
                "slo_breaches".into(),
                crate::json::Value::Num(
                    self.inner.slo_breaches.load(Ordering::Relaxed) as f64
                ),
            );
        }
        v
    }

    /// Windowed rates: cross-shard totals over the trailing `window_s`
    /// seconds (the `{"op":"stats","window":…}` payload). Windows ≤ 60 s
    /// read the per-second ring at full resolution; up to 3600 s read the
    /// per-minute rollup.
    pub fn windowed_stats_json(&self, window_s: u64) -> crate::json::Value {
        let now_s = self.inner.now_s();
        let mut v = self.inner.window_totals(now_s, window_s).json();
        if let crate::json::Value::Obj(m) = &mut v {
            m.insert("now_s".into(), crate::json::Value::Num(now_s as f64));
        }
        v
    }

    /// The full Prometheus text exposition: every merged per-shard counter,
    /// histogram, and latency digest plus the service-level gauges
    /// (pending, workers, subscribers, trace/subscription loss, SLO
    /// breaches). Served by `{"op":"metrics"}` and `serve --metrics-out`.
    pub fn prometheus_text(&self) -> String {
        let mut agg = Metrics::default();
        for shard in &self.inner.shards {
            agg.merge(&shard.metrics.lock().unwrap());
        }
        let mut w = PromWriter::new();
        agg.prometheus_into(&mut w);
        w.gauge("unipc_pending", "Jobs currently queued across all shards.", self.pending() as f64);
        w.gauge("unipc_shards", "Coordinator shard count.", self.shards() as f64);
        w.gauge("unipc_workers_alive", "Live worker threads.", self.workers_alive() as f64);
        let (mut recorded, mut dropped) = (0u64, 0u64);
        for s in &self.inner.shards {
            let tr = s.trace.lock().unwrap();
            recorded += tr.recorded();
            dropped += tr.dropped();
        }
        w.counter("unipc_trace_recorded_total", "Span events recorded into trace rings.", recorded as f64);
        w.counter("unipc_trace_dropped_total", "Span events overwritten by ring wrap.", dropped as f64);
        w.gauge("unipc_subscribers", "Live push-channel subscribers.", self.inner.hub.active() as f64);
        w.counter("unipc_sub_dropped_total", "Events a full subscriber queue could not accept.", self.inner.hub.dropped() as f64);
        w.counter(
            "unipc_slo_breaches_total",
            "slo_breach events emitted by the burn-rate monitors.",
            self.inner.slo_breaches.load(Ordering::Relaxed) as f64,
        );
        w.finish()
    }

    /// Register a push-channel subscriber with a queue bounded at `cap`
    /// events. From this moment until [`Service::unsubscribe`], every span
    /// recorded anywhere in the service (and every SLO breach) is either
    /// delivered to this queue or counted in `sub_dropped` — never silently
    /// lost, even when the trace ring wraps.
    pub fn subscribe(&self, cap: usize) -> Arc<Subscription> {
        self.inner.hub.subscribe(cap)
    }

    /// Deregister a push-channel subscriber.
    pub fn unsubscribe(&self, sub: &Arc<Subscription>) {
        self.inner.hub.unsubscribe(sub);
    }

    /// The configured per-subscriber queue capacity (`ServerConfig::sub_buf`).
    pub fn sub_buf(&self) -> usize {
        self.inner.cfg.sub_buf
    }

    /// Events full subscriber queues could not accept (cumulative).
    pub fn sub_dropped(&self) -> u64 {
        self.inner.hub.dropped()
    }

    /// `slo_breach` events emitted since boot.
    pub fn slo_breaches(&self) -> u64 {
        self.inner.slo_breaches.load(Ordering::Relaxed)
    }

    /// Force one SLO evaluation right now (the monitor thread ticks every
    /// `SLO_TICK` anyway; tests and the demo use this for determinism).
    /// Returns how many breach events fired.
    pub fn poke_slos(&self) -> usize {
        self.inner.evaluate_slos(self.inner.now_s())
    }

    /// One snapshot per shard, in shard order. For every counter and
    /// histogram bucket these sum field-wise to the aggregate
    /// [`Service::metrics_json`]; percentile fields do not sum (the
    /// aggregate recomputes them from the merged raw samples).
    pub fn shard_metrics_json(&self) -> Vec<crate::json::Value> {
        self.inner
            .shards
            .iter()
            .map(|s| s.metrics.lock().unwrap().snapshot_json())
            .collect()
    }

    /// Every span event currently retained across the per-shard rings,
    /// sorted by timestamp (ties broken by trace id). A point-in-time copy:
    /// each shard's ring is locked only long enough to snapshot it.
    pub fn trace_events(&self) -> Vec<SpanEvent> {
        let mut events: Vec<SpanEvent> = Vec::new();
        for shard in &self.inner.shards {
            events.extend(shard.trace.lock().unwrap().snapshot());
        }
        events.sort_by_key(|e| (e.start_us, e.trace_id));
        events
    }

    /// Span trees for the most recent `limit` admitted requests (the
    /// `{"op":"trace"}` wire payload). See [`crate::trace::span_trees_json`]
    /// for the shape.
    pub fn trace_json(&self, limit: usize) -> crate::json::Value {
        crate::trace::span_trees_json(&self.trace_events(), limit)
    }

    /// Chrome `trace_event`-format export of every retained span event;
    /// load the serialized form in `chrome://tracing` or Perfetto.
    pub fn chrome_trace_json(&self) -> crate::json::Value {
        crate::trace::chrome_trace_json(&self.trace_events())
    }

    /// The number of coordinator shards this service runs.
    pub fn shards(&self) -> usize {
        self.inner.shards.len()
    }

    /// The shard a request would route to: `Some(shard)` for batchable
    /// requests (a pure function of the batch key), `None` for solo jobs
    /// (placed round-robin at submit time). Introspection hook for the
    /// routing-invariant tests and shard-aware load drivers.
    pub fn route_of(&self, req: &SampleRequest) -> Option<usize> {
        let (_, key) = admission_setup(&self.inner, req);
        key.map(|k| shard_for_key(&k, self.inner.shards.len()))
    }

    pub fn pending(&self) -> usize {
        self.inner.shards.iter().map(|s| s.queue.lock().unwrap().len()).sum()
    }

    pub fn dim(&self) -> usize {
        self.inner.backend.dim()
    }

    /// Number of live (not yet finished) worker threads across all shards.
    /// The supervisor keeps this at `cfg.workers`; a retiring thread may
    /// transiently still count while its replacement is already live.
    pub fn workers_alive(&self) -> usize {
        self.inner.handles.lock().unwrap().iter().filter(|(_, h)| !h.is_finished()).count()
    }

    /// Number of live worker threads homed on `shard`. The supervisor
    /// respawns a panicked worker under its original id, so each shard's
    /// sub-pool size (`workers / shards`, ±1) is itself an invariant.
    pub fn shard_workers_alive(&self, shard: usize) -> usize {
        self.inner
            .handles
            .lock()
            .unwrap()
            .iter()
            .filter(|(home, h)| *home == shard && !h.is_finished())
            .count()
    }

    /// Stop the pool: give workers `cfg.drain_deadline_ms` to drain every
    /// shard queue, shed whatever is left with typed responses (no receiver
    /// is ever left hanging), then join every worker. The drain bound is
    /// global — all shards drain concurrently within one window, so a
    /// shard-count change never changes how long shutdown can take.
    /// Idempotent.
    pub fn shutdown(&self) {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        for shard in &self.inner.shards {
            shard.cv.notify_all();
        }

        // Bounded drain: workers keep popping until the flag stops them at
        // an empty queue.
        let drain_until =
            Instant::now() + Duration::from_millis(self.inner.cfg.drain_deadline_ms);
        while Instant::now() < drain_until {
            if self.inner.shards.iter().all(|s| s.queue.lock().unwrap().is_empty()) {
                break;
            }
            for shard in &self.inner.shards {
                shard.cv.notify_all();
            }
            std::thread::sleep(Duration::from_millis(1));
        }

        // Shed stragglers with a typed response so every receiver resolves,
        // charging each shed job to the shard that held it.
        for shard in &self.inner.shards {
            let shed: Vec<QueuedJob> = shard.queue.lock().unwrap().drain(..).collect();
            if !shed.is_empty() {
                let now_s = self.inner.now_s();
                let mut m = shard.metrics.lock().unwrap();
                for _ in &shed {
                    m.record_failure(now_s, FailureKind::BackendError);
                }
            }
            for job in shed {
                let _ = job.reply.send(SampleResponse::failure(
                    FailureKind::BackendError,
                    "service shut down before execution".into(),
                ));
            }
        }

        // Join the pool. The shutdown flag is checked under no lock, so a
        // worker can race past its check and block on the condvar after our
        // notify — keep re-notifying until each thread actually exits
        // (spin-join) rather than risking a lost-wakeup deadlock. Idle
        // workers additionally time out every STEAL_POLL, so no wakeup can
        // stay lost for long even without the re-notify.
        loop {
            let handle = {
                let mut handles = self.inner.handles.lock().unwrap();
                handles.pop()
            };
            let (_, h) = match handle {
                Some(h) => h,
                None => break,
            };
            while !h.is_finished() {
                for shard in &self.inner.shards {
                    shard.cv.notify_all();
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            if let Err(p) = h.join() {
                std::panic::resume_unwind(p);
            }
        }

        // The SLO monitor checks the shutdown flag every tick; join it
        // after the workers so its last evaluation sees final counters.
        let monitor = self.inner.monitor_handle.lock().unwrap().take();
        if let Some(h) = monitor {
            let _ = h.join();
        }
    }
}

/// Resolve a request's effective deadline in ms: per-request override, else
/// the server default; 0 from either source disables it.
fn resolve_deadline_ms(cfg: &ServerConfig, req: &SampleRequest) -> Option<u64> {
    let ms = req.deadline_ms.unwrap_or(cfg.default_deadline_ms);
    if ms == 0 {
        None
    } else {
        Some(ms)
    }
}

/// Spawn one worker and record its handle tagged with its home shard
/// (pruning handles of threads that already exited, so the vec stays
/// bounded under churn). A worker's home is a pure function of its id, so
/// a supervisor respawn lands the replacement on the same shard.
fn spawn_worker(inner: &Arc<Inner>, id: usize) {
    let arc = Arc::clone(inner);
    let home = id % inner.shards.len();
    let handle = std::thread::Builder::new()
        .name(format!("sampler-{id}"))
        .spawn(move || worker_loop(arc, id))
        .expect("spawn sampler worker");
    let mut handles = inner.handles.lock().unwrap();
    handles.retain(|(_, h)| !h.is_finished());
    handles.push((home, handle));
}

/// Supervision: when a worker retires (caught panic ⇒ possibly-corrupt
/// pooled state) or unwinds past the loop entirely, its drop respawns a
/// replacement so the pool size is an invariant. No respawn once shutdown
/// has begun.
struct RespawnGuard {
    inner: Arc<Inner>,
    id: usize,
    retire: bool,
}

impl Drop for RespawnGuard {
    fn drop(&mut self) {
        if self.inner.shutdown.load(Ordering::SeqCst) {
            return;
        }
        if self.retire || std::thread::panicking() {
            // Charge the restart to the retiring worker's home shard.
            // `if let Ok`: never double-panic in a Drop over a metrics lock
            // that the panicking thread might have poisoned.
            let home = self.id % self.inner.shards.len();
            if let Ok(mut m) = self.inner.shards[home].metrics.lock() {
                m.worker_restarts += 1;
            }
            spawn_worker(&self.inner, self.id);
        }
    }
}

fn worker_loop(inner: Arc<Inner>, id: usize) {
    let mut guard = RespawnGuard { inner: Arc::clone(&inner), id, retire: false };
    let home = id % inner.shards.len();
    // One pooled workspace per worker, reused across every batched run it
    // executes (the `workspace_reuses` metric counts successful reuse).
    let mut scratch = BatchWorkspace::new();
    // Per-worker span-event staging: events accumulate here during a run
    // and flush to the owner shard's ring under one lock. The vec is
    // reserved up front per run, so steady-state recording never allocates.
    let mut spans = Vec::new();
    // Per-worker solver-health accumulator, reset per run: plain Copy
    // state, so the observed path stays allocation-free.
    let mut health = HealthAccum::default();
    loop {
        let (job, owner) = match next_job(&inner, home) {
            Some(pair) => pair,
            None => return,
        };
        // The job stays attributed to the shard that queued it, whoever
        // runs it: batching scans the owner's queue (the rest of the
        // cohort lives there) and metrics land on the owner's store.
        let shard = &inner.shards[owner];
        let job = match shed_if_expired(&inner, shard, job) {
            Some(j) => j,
            None => continue,
        };
        let tainted = match batch_setup(&inner, shard, &job) {
            Some((opts, plan, key)) => {
                let gather_started = Instant::now();
                let mut jobs = vec![job];
                gather_batch(&inner, shard, &key, &mut jobs);
                execute_batch(
                    &inner,
                    shard,
                    &mut scratch,
                    &mut spans,
                    &mut health,
                    jobs,
                    &opts,
                    &plan,
                    gather_started,
                )
            }
            None => execute_solo(&inner, shard, job),
        };
        if tainted {
            // A caught panic may have left the pooled workspace (or any
            // worker-local state) inconsistent: retire fail-stop and let
            // the supervisor bring up a clean replacement.
            guard.retire = true;
            return;
        }
    }
}

/// Pop the next job for a worker homed on `home`: the home queue first,
/// then the other shards in ring order (work stealing — a steal is counted
/// against the shard it came from). When everything is dry, waits on the
/// home condvar with a `STEAL_POLL` timeout: submits only notify the
/// routed shard, so the bounded wait is what lets this worker notice a hot
/// queue elsewhere. Returns `None` on shutdown with all queues empty.
fn next_job(inner: &Inner, home: usize) -> Option<(QueuedJob, usize)> {
    let n = inner.shards.len();
    loop {
        for off in 0..n {
            let idx = (home + off) % n;
            let job = inner.shards[idx].queue.lock().unwrap().pop_front();
            if let Some(job) = job {
                if off != 0 {
                    inner.shards[idx].metrics.lock().unwrap().record_steal(inner.now_s());
                }
                if inner.cfg.trace.lifecycle() {
                    let now = Instant::now();
                    // Route: owner shard in `a`; `b` = 0 for a home pop,
                    // else the stealing worker's home shard + 1 — steals
                    // stay attributed to the victim shard, matching the
                    // `steals` counter.
                    let route = SpanEvent {
                        trace_id: job.trace_id,
                        parent: 0,
                        stage: Stage::Route,
                        shard: idx as u32,
                        start_us: inner.rel_us(now),
                        dur_us: 0,
                        a: idx as u64,
                        b: if off != 0 { home as u64 + 1 } else { 0 },
                    };
                    let queue = SpanEvent {
                        trace_id: job.trace_id,
                        parent: 0,
                        stage: Stage::Queue,
                        shard: idx as u32,
                        start_us: inner.rel_us(job.enqueued),
                        dur_us: now.saturating_duration_since(job.enqueued).as_micros() as u64,
                        a: 0,
                        b: 0,
                    };
                    inner.record_spans(&inner.shards[idx], &[route, queue]);
                }
                return Some((job, idx));
            }
        }
        let q = inner.shards[home].queue.lock().unwrap();
        if q.is_empty() {
            if inner.shutdown.load(Ordering::SeqCst) {
                return None;
            }
            // Timed wait, not a bare wait: no one notifies this condvar
            // for work that routed elsewhere, and the rescan above is the
            // only way to see it.
            let _ = inner.shards[home].cv.wait_timeout(q, STEAL_POLL).unwrap();
        }
    }
}

/// Shed `job` with a typed `DeadlineExceeded` response if its deadline has
/// passed; expired jobs are never executed. The failure is recorded on the
/// shard that owned the job's queue.
fn shed_if_expired(inner: &Inner, shard: &Shard, job: QueuedJob) -> Option<QueuedJob> {
    let expired = job.deadline.is_some_and(|d| Instant::now() >= d);
    if expired {
        shed_expired(inner, shard, job);
        None
    } else {
        Some(job)
    }
}

fn shed_expired(inner: &Inner, shard: &Shard, job: QueuedJob) {
    let waited = job.enqueued.elapsed();
    shard
        .metrics
        .lock()
        .unwrap()
        .record_failure(inner.now_s(), FailureKind::DeadlineExceeded);
    if inner.cfg.trace.lifecycle() {
        inner.record_span(
            shard,
            SpanEvent {
                trace_id: job.trace_id,
                parent: 0,
                stage: Stage::Respond,
                shard: shard.id,
                start_us: inner.rel_us(job.enqueued),
                dur_us: waited.as_micros() as u64,
                a: FailureKind::DeadlineExceeded.index() as u64 + 1,
                b: 0,
            },
        );
    }
    let mut resp = SampleResponse::failure(
        FailureKind::DeadlineExceeded,
        format!("deadline exceeded after {}us in queue", waited.as_micros()),
    );
    resp.queue_us = waited.as_micros() as u64;
    resp.trace_id = job.trace_id;
    let _ = job.reply.send(resp);
}

/// Resolve the batched-execution setup for a popped job from its
/// admission-time fields: the solver options, the shared cached plan, and
/// the batch key grouping requests able to run in one lockstep batch.
/// `None` routes the job to the solo reference path (unplannable method).
fn batch_setup(
    inner: &Inner,
    shard: &Shard,
    job: &QueuedJob,
) -> Option<(SampleOptions, Arc<SamplePlan>, String)> {
    let key = job.batch_key.clone()?;
    let opts = job.opts.clone()?;
    let plan = lookup_plan(inner, shard, &opts)?;
    Some((opts, plan, key))
}

/// Admission-time resolution, done once per request ([`Service::submit`])
/// and stored on the queued job: the full solver options and, for
/// plannable configurations, the batch key — the [`plan_key`] alone, so
/// requests that share a sampling plan batch together regardless of model
/// conditioning (the worker builds a row-conditioned [`CohortModel`]
/// instead of requiring one shared view). The legacy keying (plan key +
/// [`SampleRequest::conditioning_key`]) is available behind
/// `ServerConfig::split_cond_batches` as the conditioning-split ablation
/// baseline. The batch key is `None` for methods plans don't cover (they
/// take the solo path). The key also routes the request: see
/// [`shard_for_key`].
fn admission_setup(
    inner: &Inner,
    req: &SampleRequest,
) -> (Option<SampleOptions>, Option<String>) {
    let opts = build_opts(inner, req).ok();
    let key = opts.as_ref().filter(|o| SamplePlan::supports(o)).map(|o| {
        let pk = plan_key(&inner.sched, o);
        if inner.cfg.split_cond_batches {
            format!("{pk}{}", req.conditioning_key())
        } else {
            pk
        }
    });
    (opts, key)
}

/// Pull queued jobs whose batch key matches `key` into `jobs`, bounded by
/// `max_batch` total rows. With a linger window configured, waits up to the
/// deadline for more same-key arrivals; with the default of 0 this is a
/// single opportunistic scan of what is already queued. Expired same-key
/// jobs found during the scan are shed, not absorbed. Scans only `shard` —
/// the shard the leader was queued on — which is where routing guarantees
/// the rest of the cohort lives, even when the leader was stolen.
fn gather_batch(inner: &Inner, shard: &Shard, key: &str, jobs: &mut Vec<QueuedJob>) {
    let mut rows: usize = jobs.iter().map(|j| j.req.n).sum();
    if rows >= inner.cfg.max_batch {
        return;
    }
    let mut deadline = Instant::now() + Duration::from_micros(inner.cfg.batch_linger_us);
    // Never linger past a member's request deadline: waiting longer only
    // adds latency to a job that is already out of slack.
    for j in jobs.iter() {
        if let Some(d) = j.deadline {
            deadline = deadline.min(d);
        }
    }
    let mut q = shard.queue.lock().unwrap();
    loop {
        let mut i = 0;
        while i < q.len() {
            if q[i].batch_key.as_deref() == Some(key) {
                if q[i].deadline.is_some_and(|d| Instant::now() >= d) {
                    // Queue lock → metrics lock is the allowed order.
                    let j = q.remove(i).expect("index in range");
                    shed_expired(inner, shard, j);
                    continue;
                }
                if rows + q[i].req.n <= inner.cfg.max_batch {
                    let j = q.remove(i).expect("index in range");
                    // Queue span for an absorbed member (the leader got its
                    // Route+Queue at pop time in `next_job`; members pulled
                    // into an in-flight assembly end their wait here).
                    // `a = 1` marks absorption; queue lock → trace lock is
                    // fine — trace locks are terminal, like metrics.
                    if inner.cfg.trace.lifecycle() {
                        inner.record_span(
                            shard,
                            SpanEvent {
                                trace_id: j.trace_id,
                                parent: 0,
                                stage: Stage::Queue,
                                shard: shard.id,
                                start_us: inner.rel_us(j.enqueued),
                                dur_us: j.enqueued.elapsed().as_micros() as u64,
                                a: 1,
                                b: 0,
                            },
                        );
                    }
                    rows += j.req.n;
                    jobs.push(j);
                    if let Some(d) = jobs.last().and_then(|j| j.deadline) {
                        deadline = deadline.min(d);
                    }
                    if rows >= inner.cfg.max_batch {
                        return;
                    }
                    continue;
                }
            }
            i += 1;
        }
        if inner.shutdown.load(Ordering::SeqCst) {
            return;
        }
        let now = Instant::now();
        if now >= deadline {
            return;
        }
        // Jobs this batch can't absorb stay queued; they are picked up as
        // soon as any worker finishes its current run (at worst one linger
        // window from now). Deliberately no re-notify here: with every
        // waiter lingering, a notify would just bounce between assemblers
        // in a busy loop for the rest of the window.
        let (guard, _timeout) = shard.cv.wait_timeout(q, deadline - now).unwrap();
        q = guard;
    }
}

/// Best-effort stringification of a panic payload for the failure message.
fn panic_message(payload: &(dyn Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

/// Execute a batch of same-key jobs in lockstep from the shared plan,
/// record per-request metrics, and reply to every member. A batch of one
/// still runs here: it reuses the worker's pooled workspace.
///
/// Returns `true` if the run panicked (the worker must retire). On a
/// mid-batch panic the cohort is quarantined: every member is re-run solo,
/// so only the member whose evaluation actually faults fails and the rest
/// produce output bit-identical to a fault-free run (the solo path executes
/// the same plan). On a clean run, each member's output rows are checked
/// for finiteness on the stacked tensor; non-finite members fail
/// individually while their cohort completes.
#[allow(clippy::too_many_arguments)]
fn execute_batch(
    inner: &Inner,
    shard: &Shard,
    scratch: &mut BatchWorkspace,
    spans: &mut Vec<SpanEvent>,
    health: &mut HealthAccum,
    mut jobs: Vec<QueuedJob>,
    opts: &SampleOptions,
    plan: &SamplePlan,
    gather_started: Instant,
) -> bool {
    // Members may differ in conditioning (the batch key is the plan key
    // alone): sort them so equal conditionings are contiguous — one slab
    // each, and a uniform cohort stays a single slab on the fast path.
    // Scatter is per-member reply channels, so the reorder is invisible to
    // clients.
    jobs.sort_by_key(|j| j.req.conditioning().order_key());
    let queue_times: Vec<Duration> = jobs.iter().map(|j| j.enqueued.elapsed()).collect();
    let started = Instant::now();
    let slabs = CondSlab::coalesce(jobs.iter().map(|j| (j.req.n, j.req.conditioning())));
    let distinct_conds = slabs.len();
    let rows: usize = jobs.iter().map(|j| j.req.n).sum();
    let level = inner.cfg.trace;
    // A multi-member batch gets a dedicated cohort id owning the shared
    // assemble/step spans, with `cohort` links tying members to it; a batch
    // of one inlines those spans straight into the member's tree.
    let cohort = if jobs.len() > 1 { inner.mint_trace_id() } else { jobs[0].trace_id };
    spans.clear();
    if level.lifecycle() {
        // One reservation covers the worst case for this run (assemble +
        // links + per-step pairs + quarantine/respond per member + retry),
        // so every push below is allocation-free.
        spans.reserve(2 * plan.len() + 3 * jobs.len() + 2);
        spans.push(SpanEvent {
            trace_id: cohort,
            parent: 0,
            stage: Stage::Assemble,
            shard: shard.id,
            start_us: inner.rel_us(gather_started),
            dur_us: started.saturating_duration_since(gather_started).as_micros() as u64,
            a: jobs.len() as u64,
            b: distinct_conds as u64,
        });
        if jobs.len() > 1 {
            for (i, job) in jobs.iter().enumerate() {
                spans.push(SpanEvent {
                    trace_id: job.trace_id,
                    parent: cohort,
                    stage: Stage::CohortLink,
                    shard: shard.id,
                    start_us: inner.rel_us(started),
                    dur_us: 0,
                    a: i as u64,
                    b: job.req.n as u64,
                });
            }
        }
    }
    let model = CohortModel::new(&inner.backend, &inner.sched, slabs);
    let dim = model.dim();
    let inits: Vec<Tensor> = jobs
        .iter()
        .map(|j| Rng::seed_from(j.req.seed).normal_tensor(&[j.req.n, dim]))
        .collect();
    let refs: Vec<&Tensor> = inits.iter().collect();
    let reuses_before = scratch.reuses();
    // The timing wrapper always runs (it feeds the model_eval/solver
    // digests); per-step span emission additionally needs `trace=steps`.
    let timed = TimedModel::new(&model);
    health.reset();
    let outcome = catch_unwind(AssertUnwindSafe(|| {
        if level.steps() {
            // HealthSpans opts into the executor's per-step health payload
            // (corrector delta + finiteness), feeding the worker-local
            // accumulator while forwarding each step to the span recorder —
            // one executor pass serves both tracing and numerical health.
            let mut obs = HealthSpans {
                spans: Some(StepSpans::new(
                    &mut *spans,
                    &timed,
                    inner.epoch,
                    cohort,
                    0,
                    shard.id,
                    rows as u64,
                )),
                accum: &mut *health,
            };
            sample_batch_with_plan_observed(
                &timed,
                &inner.sched,
                &refs,
                opts,
                plan,
                scratch,
                Some(&mut obs),
            )
        } else {
            sample_batch_with_plan_observed(
                &timed, &inner.sched, &refs, opts, plan, scratch, None,
            )
        }
    }));
    let compute_time = started.elapsed();
    let model_time = Duration::from_nanos(timed.eval_ns()).min(compute_time);

    let results = match outcome {
        Ok(results) => results,
        Err(payload) => {
            let msg = panic_message(payload.as_ref());
            if jobs.len() > 1 {
                // Quarantine: re-run every member solo so only the actual
                // culprit fails; the others stay bit-identical to a clean
                // run (solo executes the same plan).
                shard.metrics.lock().unwrap().batch_retries += jobs.len() as u64;
                if level.lifecycle() {
                    spans.push(SpanEvent {
                        trace_id: cohort,
                        parent: 0,
                        stage: Stage::Retry,
                        shard: shard.id,
                        start_us: inner.rel_us(Instant::now()),
                        dur_us: 0,
                        a: jobs.len() as u64,
                        b: 0,
                    });
                    inner.record_spans(shard, spans);
                }
                for job in jobs {
                    let _ = execute_solo(inner, shard, job);
                }
            } else {
                // A batch of one has no cohort to protect; fail it typed.
                if level.lifecycle() {
                    inner.record_spans(shard, spans);
                }
                let job = jobs.into_iter().next().expect("non-empty batch");
                let resp = SampleResponse::failure(
                    FailureKind::WorkerPanic,
                    format!("worker panicked during execution: {msg}"),
                );
                finish_solo(
                    inner,
                    shard,
                    job,
                    resp,
                    queue_times[0],
                    compute_time,
                    Duration::ZERO,
                );
            }
            return true;
        }
    };

    // Per-member finiteness on the stacked output: kernels in the planned
    // path are row-independent, so a NaN/Inf row can only have poisoned the
    // member that owns it — quarantine exactly those members.
    let finite: Vec<bool> = {
        let stacked = scratch.stacked();
        let mut row = 0usize;
        jobs.iter()
            .map(|j| {
                let ok = stacked.rows_finite(row, j.req.n);
                row += j.req.n;
                ok
            })
            .collect()
    };

    let now_s = inner.now_s();
    let mut m = shard.metrics.lock().unwrap();
    // The leader's lookup_plan counted its own hit/build; followers were
    // absorbed without a lookup but are equally served from the cached
    // plan, so count them as hits to keep plan_hits per-request.
    m.plan_hits += jobs.len() as u64 - 1;
    m.record_batch(now_s, jobs.len(), distinct_conds, scratch.reuses() - reuses_before);
    if level.steps() {
        // One health record per run: the observer saw the whole cohort's
        // stacked state, so its delta norms and non-finite provenance are
        // cohort-level signals.
        m.record_health(health.mean_delta(), health.first_nonfinite);
    }
    for ((job, r), (qt, ok)) in
        jobs.iter().zip(results.iter()).zip(queue_times.iter().zip(&finite))
    {
        if *ok {
            m.record_completion(
                now_s,
                job.req.n,
                r.nfe,
                *qt,
                compute_time,
                model_time,
                job.trace_id,
            );
        } else {
            m.quarantined_members += 1;
            m.record_failure(now_s, FailureKind::NonFiniteOutput);
        }
    }
    drop(m);

    for (i, ((job, r), (qt, ok))) in jobs
        .into_iter()
        .zip(results)
        .zip(queue_times.into_iter().zip(finite))
        .enumerate()
    {
        let mut resp = if ok {
            SampleResponse::success(
                r.nfe,
                job.req.return_samples.then(|| r.x.data().to_vec()),
                dim,
            )
        } else {
            let mut f = SampleResponse::failure(
                FailureKind::NonFiniteOutput,
                "solver produced non-finite output for this request".into(),
            );
            f.nfe = r.nfe;
            f.dim = dim;
            f
        };
        resp.queue_us = qt.as_micros() as u64;
        resp.compute_us = compute_time.as_micros() as u64;
        resp.model_eval_us = model_time.as_micros() as u64;
        // Integer subtraction (not Duration math) so the stamped split
        // sums to compute_us exactly despite µs truncation.
        resp.solver_us = resp.compute_us - resp.model_eval_us;
        resp.trace_id = job.trace_id;
        if level.steps() {
            // Cohort-level numerical health stamped on every member (the
            // solver state is stacked, so the signal is shared).
            resp.corrector_delta_mean = health.mean_delta();
            resp.corrector_delta_max =
                (health.corrected_steps > 0).then_some(health.delta_max);
            resp.first_nonfinite_step = health.first_nonfinite;
        }
        if level.lifecycle() {
            if !ok {
                spans.push(SpanEvent {
                    trace_id: job.trace_id,
                    parent: cohort,
                    stage: Stage::Quarantine,
                    shard: shard.id,
                    start_us: inner.rel_us(Instant::now()),
                    dur_us: 0,
                    a: i as u64,
                    b: FailureKind::NonFiniteOutput.index() as u64,
                });
            }
            spans.push(SpanEvent {
                trace_id: job.trace_id,
                parent: 0,
                stage: Stage::Respond,
                shard: shard.id,
                start_us: inner.rel_us(job.enqueued),
                dur_us: (qt + compute_time).as_micros() as u64,
                a: if ok { 0 } else { FailureKind::NonFiniteOutput.index() as u64 + 1 },
                b: r.nfe as u64,
            });
        }
        let _ = job.reply.send(resp);
    }
    if level.lifecycle() {
        inner.record_spans(shard, spans);
    }
    false
}

/// The solo path: unplannable methods, parse failures, and quarantined
/// batch-member retries. Returns `true` if the run panicked (the worker
/// must retire). Metrics land on `shard` — the shard that owned the job.
fn execute_solo(inner: &Inner, shard: &Shard, job: QueuedJob) -> bool {
    let queue_time = job.enqueued.elapsed();
    let started = Instant::now();
    let outcome = catch_unwind(AssertUnwindSafe(|| {
        run_request(inner, &job.req, job.opts.as_ref())
    }));
    let compute_time = started.elapsed();
    match outcome {
        Ok((resp, model_time)) => {
            finish_solo(inner, shard, job, resp, queue_time, compute_time, model_time);
            false
        }
        Err(payload) => {
            let resp = SampleResponse::failure(
                FailureKind::WorkerPanic,
                format!(
                    "worker panicked during execution: {}",
                    panic_message(payload.as_ref())
                ),
            );
            finish_solo(inner, shard, job, resp, queue_time, compute_time, Duration::ZERO);
            true
        }
    }
}

/// Record metrics for a solo outcome, stamp latencies (including the
/// model-eval/solver split of compute), record the terminal `respond`
/// span, and reply.
fn finish_solo(
    inner: &Inner,
    shard: &Shard,
    job: QueuedJob,
    mut resp: SampleResponse,
    queued: Duration,
    compute: Duration,
    model_eval: Duration,
) {
    let model_eval = model_eval.min(compute);
    {
        let now_s = inner.now_s();
        let mut m = shard.metrics.lock().unwrap();
        match resp.kind {
            None => m.record_completion(
                now_s,
                job.req.n,
                resp.nfe,
                queued,
                compute,
                model_eval,
                job.trace_id,
            ),
            Some(k) => m.record_failure(now_s, k),
        }
    }
    if inner.cfg.trace.lifecycle() {
        inner.record_span(
            shard,
            SpanEvent {
                trace_id: job.trace_id,
                parent: 0,
                stage: Stage::Respond,
                shard: shard.id,
                start_us: inner.rel_us(job.enqueued),
                dur_us: (queued + compute).as_micros() as u64,
                a: resp.kind.map_or(0, |k| k.index() as u64 + 1),
                b: resp.nfe as u64,
            },
        );
    }
    resp.queue_us = queued.as_micros() as u64;
    resp.compute_us = compute.as_micros() as u64;
    resp.model_eval_us = model_eval.as_micros() as u64;
    // Integer subtraction (not Duration math) so the stamped split sums to
    // compute_us exactly despite µs truncation.
    resp.solver_us = resp.compute_us - resp.model_eval_us;
    resp.trace_id = job.trace_id;
    let _ = job.reply.send(resp);
}

/// Fetch (or build and cache) the shared plan for this solver config.
/// Returns `None` for configurations plans don't cover; those run the
/// reference loop. The cache is global; the hit/build counters land on the
/// executing worker's current shard.
fn lookup_plan(inner: &Inner, shard: &Shard, opts: &SampleOptions) -> Option<Arc<SamplePlan>> {
    if !SamplePlan::supports(opts) {
        return None;
    }
    let key = plan_key(&inner.sched, opts);
    {
        let mut plans = inner.plans.lock().unwrap();
        if let Some(p) = plans.get(&key) {
            drop(plans);
            shard.metrics.lock().unwrap().plan_hits += 1;
            return Some(p);
        }
    }
    let built = Arc::new(SamplePlan::build(&inner.sched, opts)?);
    let (shared, inserted) = {
        let mut plans = inner.plans.lock().unwrap();
        // Two workers may race to build the same plan; keep the first so
        // later requests all share one allocation, and count the loser as
        // a hit (plan_builds = distinct configs actually cached). Only a
        // genuinely new config evicts: a lost race must not shrink the
        // cache.
        if let Some(p) = plans.get(&key) {
            (p, false)
        } else {
            plans.insert(key, Arc::clone(&built));
            (built, true)
        }
    };
    let mut m = shard.metrics.lock().unwrap();
    if inserted {
        m.plan_builds += 1;
    } else {
        m.plan_hits += 1;
    }
    drop(m);
    Some(shared)
}

/// Resolve a request's full solver options against the server defaults.
fn build_opts(inner: &Inner, req: &SampleRequest) -> anyhow::Result<SampleOptions> {
    let method = req.parsed_method()?;
    let mut opts = SampleOptions::new(method, req.steps);
    opts.spacing = inner.cfg.spacing;
    opts.t_start = inner.cfg.t_start;
    opts.t_end = inner.cfg.t_end;
    if req.unic {
        // UniC inherits the base method's coefficient variant when the base
        // is UniP (UniPC proper); B₂ otherwise.
        let variant = match &opts.method {
            crate::solver::Method::UniP { variant, .. } => *variant,
            _ => CoeffVariant::Bh(crate::numerics::vandermonde::BFunction::Bh2),
        };
        opts = opts.with_unic(variant, false);
    }
    Ok(opts)
}

fn run_request(
    inner: &Inner,
    req: &SampleRequest,
    opts: Option<&SampleOptions>,
) -> (SampleResponse, Duration) {
    // `opts` is the admission-time resolution; absent means the method
    // failed to parse, so re-run the build to produce the error message.
    let opts = match opts {
        Some(o) => o.clone(),
        None => match build_opts(inner, req) {
            Ok(o) => o,
            Err(e) => {
                return (
                    SampleResponse::failure(FailureKind::InvalidRequest, format!("{e:#}")),
                    Duration::ZERO,
                )
            }
        },
    };
    let model = CohortModel::solo(&inner.backend, &inner.sched, req.conditioning(), req.n);
    let dim = model.dim();

    let mut rng = Rng::seed_from(req.seed);
    let x_t = rng.normal_tensor(&[req.n, dim]);
    // Plannable configs take the planned path inside `sample` too, so a
    // quarantined batch member re-run here is bit-identical to its batch.
    // The timing wrapper splits compute into model-eval vs solver time for
    // the response stamps and latency digests.
    let timed = TimedModel::new(&model);
    let result = sample(&timed, &inner.sched, &x_t, &opts);
    let model_time = Duration::from_nanos(timed.eval_ns());

    if !result.x.rows_finite(0, req.n) {
        let mut f = SampleResponse::failure(
            FailureKind::NonFiniteOutput,
            "solver produced non-finite output for this request".into(),
        );
        f.nfe = result.nfe;
        f.dim = dim;
        return (f, model_time);
    }
    let resp = SampleResponse::success(
        result.nfe,
        req.return_samples.then(|| result.x.data().to_vec()),
        dim,
    );
    (resp, model_time)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytic::datasets::{dataset, DatasetSpec};

    fn analytic_service(workers: usize, queue_cap: usize) -> Service {
        let spec = DatasetSpec::Cifar10Like;
        let gm = Arc::new(dataset(spec));
        let classes = (0..spec.n_classes()).map(|c| spec.class_components(c)).collect();
        let mut cfg = ServerConfig { workers, queue_cap, ..Default::default() };
        cfg.default_steps = 5;
        Service::start(
            cfg,
            ModelBackend::Analytic { gm, class_components: Arc::new(classes) },
        )
    }

    #[test]
    fn sample_roundtrip_deterministic() {
        let svc = analytic_service(2, 16);
        let req = SampleRequest { n: 3, steps: 6, seed: 42, ..Default::default() };
        let a = svc.sample_blocking(req.clone());
        let b = svc.sample_blocking(req);
        assert!(a.ok, "{:?}", a.error);
        assert_eq!(a.nfe, 6);
        assert_eq!(a.samples, b.samples, "same seed ⇒ same samples");
        assert_eq!(a.samples.as_ref().unwrap().len(), 3 * svc.dim());
        svc.shutdown();
    }

    #[test]
    fn invalid_requests_rejected() {
        let svc = analytic_service(1, 4);
        let bad = SampleRequest { n: 0, ..Default::default() };
        let r = svc.sample_blocking(bad);
        assert!(!r.ok);
        assert_eq!(r.kind, Some(FailureKind::InvalidRequest));
        let bad2 = SampleRequest { method: "nope".into(), ..Default::default() };
        assert!(!svc.sample_blocking(bad2).ok);
        let m = svc.metrics_json();
        assert_eq!(m.get("rejected").unwrap().as_f64(), Some(2.0));
        assert_eq!(m.get("invalid_request").unwrap().as_f64(), Some(2.0));
        svc.shutdown();
    }

    #[test]
    fn guided_requests_differ_from_unconditional() {
        let svc = analytic_service(2, 16);
        let base = SampleRequest { n: 2, steps: 5, seed: 7, ..Default::default() };
        let uncond = svc.sample_blocking(base.clone());
        let guided = svc.sample_blocking(SampleRequest {
            class: Some(1),
            guidance: Some(4.0),
            ..base
        });
        assert!(uncond.ok && guided.ok);
        assert_ne!(uncond.samples, guided.samples);
        svc.shutdown();
    }

    #[test]
    fn concurrent_load_all_complete() {
        let svc = analytic_service(4, 64);
        let handles: Vec<_> = (0..16)
            .map(|i| {
                let svc = svc.clone();
                std::thread::spawn(move || {
                    svc.sample_blocking(SampleRequest {
                        n: 2,
                        steps: 5,
                        seed: i,
                        return_samples: false,
                        ..Default::default()
                    })
                })
            })
            .collect();
        for h in handles {
            assert!(h.join().unwrap().ok);
        }
        let m = svc.metrics_json();
        assert_eq!(m.get("completed").unwrap().as_f64(), Some(16.0));
        svc.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // 1 worker, tiny queue, slow-ish requests: eventually rejects.
        let svc = analytic_service(1, 2);
        let mut rejected = 0;
        let mut receivers = Vec::new();
        for i in 0..20 {
            match svc.submit(SampleRequest {
                n: 4,
                steps: 40,
                seed: i,
                return_samples: false,
                ..Default::default()
            }) {
                Ok(rx) => receivers.push(rx),
                Err(resp) => {
                    assert_eq!(resp.kind, Some(FailureKind::QueueFull));
                    rejected += 1;
                }
            }
        }
        assert!(rejected > 0, "queue cap must reject under overload");
        for rx in receivers {
            let _ = rx.recv();
        }
        svc.shutdown();
    }

    #[test]
    fn plan_cache_shared_across_same_config_requests() {
        let svc = analytic_service(2, 16);
        let req = SampleRequest { n: 2, steps: 6, seed: 1, ..Default::default() };
        assert!(svc.sample_blocking(req.clone()).ok);
        // Same solver config, different seed: must hit the cached plan.
        assert!(svc.sample_blocking(SampleRequest { seed: 2, ..req.clone() }).ok);
        let m = svc.metrics_json();
        assert_eq!(m.get("plan_builds").unwrap().as_f64(), Some(1.0));
        assert_eq!(m.get("plan_hits").unwrap().as_f64(), Some(1.0));
        // A different config builds its own plan.
        assert!(svc.sample_blocking(SampleRequest { steps: 7, seed: 3, ..req }).ok);
        let m = svc.metrics_json();
        assert_eq!(m.get("plan_builds").unwrap().as_f64(), Some(2.0));
        assert_eq!(m.get("plan_hits").unwrap().as_f64(), Some(1.0));
        // Non-UniPC methods are plan-cached too (the whole zoo compiles):
        // the first dpmpp-2m request builds, the second hits.
        let baseline = SampleRequest {
            method: "dpmpp-2m".into(),
            unic: false,
            seed: 4,
            ..Default::default()
        };
        assert!(svc.sample_blocking(baseline.clone()).ok);
        assert!(svc.sample_blocking(SampleRequest { seed: 5, ..baseline }).ok);
        let m = svc.metrics_json();
        assert_eq!(m.get("plan_builds").unwrap().as_f64(), Some(3.0));
        assert_eq!(m.get("plan_hits").unwrap().as_f64(), Some(2.0));
        svc.shutdown();
    }

    #[test]
    fn batched_execution_matches_solo_and_counts_metrics() {
        // One worker with a generous linger window: rapid-fire same-config
        // submissions coalesce into a lockstep batched run; the serialized
        // first pass runs each request as a batch of one. Both paths must
        // produce bit-identical samples.
        let spec = DatasetSpec::Cifar10Like;
        let gm = Arc::new(dataset(spec));
        let classes = (0..spec.n_classes()).map(|c| spec.class_components(c)).collect();
        let cfg = ServerConfig {
            workers: 1,
            queue_cap: 64,
            batch_linger_us: 50_000,
            ..Default::default()
        };
        let svc = Service::start(
            cfg,
            ModelBackend::Analytic { gm, class_components: Arc::new(classes) },
        );
        let reqs: Vec<SampleRequest> = (0..6)
            .map(|i| SampleRequest { n: 2, steps: 5, seed: i, ..Default::default() })
            .collect();
        let solo: Vec<Vec<f64>> = reqs
            .iter()
            .map(|r| svc.sample_blocking(r.clone()).samples.unwrap())
            .collect();
        let rxs: Vec<_> = reqs.iter().map(|r| svc.submit(r.clone()).unwrap()).collect();
        let batched: Vec<Vec<f64>> = rxs
            .into_iter()
            .map(|rx| rx.recv().unwrap().samples.unwrap())
            .collect();
        assert_eq!(solo, batched, "batched execution must be bit-identical to solo");

        let m = svc.metrics_json();
        assert_eq!(m.get("completed").unwrap().as_f64(), Some(12.0));
        assert!(
            m.get("batched_runs").unwrap().as_f64().unwrap() >= 1.0,
            "concurrent same-config requests must coalesce: {m:?}"
        );
        assert!(
            m.get("workspace_reuses").unwrap().as_f64().unwrap() >= 1.0,
            "per-worker workspace must be reused across runs: {m:?}"
        );
        svc.shutdown();
    }

    #[test]
    fn methods_dispatch_through_service() {
        let svc = analytic_service(2, 16);
        for method in ["ddim", "dpmpp-2m", "dpmpp-3m", "unipc-2-bh1", "pndm", "deis-2"] {
            let r = svc.sample_blocking(SampleRequest {
                n: 1,
                steps: 6,
                method: method.into(),
                unic: false,
                seed: 1,
                ..Default::default()
            });
            assert!(r.ok, "{method}: {:?}", r.error);
            assert!(r.samples.unwrap().iter().all(|v| v.is_finite()), "{method}");
        }
        svc.shutdown();
    }

    #[test]
    fn plan_cache_lru_keeps_hot_entry_under_churn() {
        let sched = VpLinear::default();
        let build = || {
            let opts = SampleOptions::new(
                crate::solver::Method::parse("dpmpp-2m").unwrap(),
                5,
            );
            Arc::new(SamplePlan::build(&sched, &opts).unwrap())
        };
        let mut cache = PlanCache::new(4);
        cache.insert("hot".into(), build());
        for i in 0..20 {
            // Touch the hot entry between every churn insert: last-use LRU
            // must keep it while cold one-shot keys cycle through.
            assert!(cache.get("hot").is_some(), "hot plan evicted at churn {i}");
            cache.insert(format!("cold-{i}"), build());
            assert!(cache.len() <= 4, "cap exceeded at churn {i}");
        }
        assert!(cache.get("hot").is_some(), "hot plan must survive churn");
        assert!(cache.get("cold-0").is_none(), "oldest cold key must be evicted");
    }

    #[test]
    fn shard_for_key_is_stable_and_in_range() {
        for shards in 1..=8usize {
            for key in ["a", "unipc-3|steps=5|class=None", "x|class=Some(3)|g=None"] {
                let s = shard_for_key(key, shards);
                assert!(s < shards);
                assert_eq!(s, shard_for_key(key, shards), "routing must be pure");
            }
        }
        // shards=0 is defended (effective_shards never produces it, but the
        // hash must not divide by zero).
        assert_eq!(shard_for_key("k", 0), 0);
    }

    #[test]
    fn route_of_batchable_is_deterministic_and_solo_is_none() {
        let svc = analytic_service(4, 64);
        assert_eq!(svc.shards(), 4, "4 workers default to 4 shards");
        let req = SampleRequest { n: 1, steps: 5, seed: 3, ..Default::default() };
        let r1 = svc.route_of(&req);
        assert!(r1.is_some(), "plannable request must have a batch-key route");
        // Seed is not part of the batch key: any seed routes identically.
        assert_eq!(r1, svc.route_of(&SampleRequest { seed: 99, ..req.clone() }));
        // Neither is conditioning: the batch key is the plan key alone, so
        // classed/guided requests colocate with the unconditional cohort.
        let classed = SampleRequest { class: Some(2), ..req.clone() };
        assert_eq!(svc.route_of(&classed), r1);
        let guided =
            SampleRequest { class: Some(2), guidance: Some(3.0), ..req.clone() };
        assert_eq!(svc.route_of(&guided), r1);
        // An unparsable method has no batch key ⇒ solo round-robin.
        let solo = SampleRequest { method: "nope".into(), ..req };
        assert_eq!(svc.route_of(&solo), None);
        svc.shutdown();
    }

    #[test]
    fn cond_slabs_coalesce_adjacent_equal_conditionings() {
        let c = |class: Option<usize>, g: Option<f64>| Conditioning { class, guidance: g };
        let slabs = CondSlab::coalesce(vec![
            (2, c(None, None)),
            (1, c(None, None)),
            (3, c(Some(1), None)),
            (1, c(Some(1), Some(2.0))),
            (2, c(Some(1), Some(2.0))),
        ]);
        assert_eq!(slabs.len(), 3);
        assert_eq!((slabs[0].start, slabs[0].rows), (0, 3));
        assert_eq!((slabs[1].start, slabs[1].rows), (3, 3));
        assert_eq!((slabs[2].start, slabs[2].rows), (6, 3));
        assert_eq!(slabs[2].cond.guidance, Some(2.0));
        // Equal conditionings that are NOT adjacent stay separate slabs —
        // coalesce preserves stacked row order (the worker's sort is what
        // makes equal conditionings adjacent).
        let split = CondSlab::coalesce(vec![
            (1, c(Some(1), None)),
            (1, c(None, None)),
            (1, c(Some(1), None)),
        ]);
        assert_eq!(split.len(), 3);
    }

    #[test]
    fn mixed_conditioning_requests_batch_together_bit_identically() {
        // One worker with a generous linger window: rapid-fire submissions
        // with distinct classes and guidance scales must coalesce into one
        // mixed-conditioning lockstep run, and every member must stay
        // bit-identical to its solo run.
        let spec = DatasetSpec::Cifar10Like;
        let gm = Arc::new(dataset(spec));
        let classes = (0..spec.n_classes()).map(|c| spec.class_components(c)).collect();
        let cfg = ServerConfig {
            workers: 1,
            queue_cap: 64,
            batch_linger_us: 50_000,
            ..Default::default()
        };
        let svc = Service::start(
            cfg,
            ModelBackend::Analytic { gm, class_components: Arc::new(classes) },
        );
        let reqs: Vec<SampleRequest> = vec![
            SampleRequest { n: 2, steps: 5, seed: 1, ..Default::default() },
            SampleRequest { n: 1, steps: 5, seed: 2, class: Some(3), ..Default::default() },
            SampleRequest {
                n: 2,
                steps: 5,
                seed: 3,
                class: Some(7),
                guidance: Some(2.0),
                ..Default::default()
            },
            SampleRequest {
                n: 1,
                steps: 5,
                seed: 4,
                class: Some(3),
                guidance: Some(0.5),
                ..Default::default()
            },
        ];
        let solo: Vec<Vec<f64>> = reqs
            .iter()
            .map(|r| {
                let resp = svc.sample_blocking(r.clone());
                assert!(resp.ok, "{:?}", resp.error);
                resp.samples.unwrap()
            })
            .collect();
        let rxs: Vec<_> = reqs.iter().map(|r| svc.submit(r.clone()).unwrap()).collect();
        let batched: Vec<Vec<f64>> = rxs
            .into_iter()
            .map(|rx| rx.recv().unwrap().samples.unwrap())
            .collect();
        assert_eq!(solo, batched, "mixed-conditioning batch must match solo bits");

        let m = svc.metrics_json();
        assert_eq!(m.get("completed").unwrap().as_f64(), Some(8.0));
        assert!(
            m.get("mixed_cond_batches").unwrap().as_f64().unwrap() >= 1.0,
            "distinct conditionings must have shared a batched run: {m:?}"
        );
        let hist = match m.get("cond_distinct_hist") {
            Some(crate::json::Value::Arr(a)) => a.clone(),
            other => panic!("missing cond_distinct_hist: {other:?}"),
        };
        assert!(
            hist.iter().skip(1).filter_map(|v| v.as_f64()).sum::<f64>() >= 1.0,
            "some batch must have had ≥ 2 distinct conditionings: {hist:?}"
        );
        svc.shutdown();
    }

    #[test]
    fn work_stealing_drains_a_foreign_shard() {
        // Every worker homes somewhere, but all 16 same-key requests route
        // to exactly one shard; with 4 workers on 4 shards, completion of
        // the whole burst proves foreign-homed workers stole from it.
        let svc = analytic_service(4, 64);
        let reqs: Vec<SampleRequest> = (0..16)
            .map(|i| SampleRequest {
                n: 1,
                steps: 5,
                seed: i,
                return_samples: false,
                ..Default::default()
            })
            .collect();
        let target = svc.route_of(&reqs[0]).unwrap();
        for r in &reqs {
            assert_eq!(svc.route_of(r), Some(target), "one cohort, one shard");
        }
        let rxs: Vec<_> = reqs.iter().map(|r| svc.submit(r.clone()).unwrap()).collect();
        for rx in rxs {
            assert!(rx.recv().unwrap().ok);
        }
        let m = svc.metrics_json();
        assert_eq!(m.get("completed").unwrap().as_f64(), Some(16.0));
        assert_eq!(m.get("shards").unwrap().as_f64(), Some(4.0));
        svc.shutdown();
    }

    #[test]
    fn submit_after_shutdown_rejected_with_typed_response() {
        let svc = analytic_service(1, 4);
        svc.shutdown();
        let r = svc.submit(SampleRequest::default());
        match r {
            Err(resp) => {
                assert!(!resp.ok);
                assert_eq!(resp.kind, Some(FailureKind::BackendError));
            }
            Ok(_) => panic!("submit after shutdown must be rejected"),
        }
        // Shutdown is idempotent.
        svc.shutdown();
    }

    #[test]
    fn traces_record_lifecycle_and_echo_trace_id() {
        let svc = analytic_service(2, 16);
        let resp = svc.sample_blocking(SampleRequest {
            n: 2,
            steps: 5,
            seed: 1,
            trace_id: Some(4242),
            ..Default::default()
        });
        assert!(resp.ok, "{:?}", resp.error);
        assert_eq!(resp.trace_id, 4242, "client-supplied trace id must echo back");
        assert!(resp.compute_us >= resp.model_eval_us);
        assert_eq!(
            resp.model_eval_us + resp.solver_us,
            resp.compute_us,
            "model/solver must split compute exactly"
        );
        // A minted id is nonzero and distinct per request.
        let a = svc.sample_blocking(SampleRequest { n: 1, steps: 5, seed: 2, ..Default::default() });
        let b = svc.sample_blocking(SampleRequest { n: 1, steps: 5, seed: 3, ..Default::default() });
        assert!(a.trace_id != 0 && b.trace_id != 0 && a.trace_id != b.trace_id);

        let events = svc.trace_events();
        let stages_of = |id: u64| -> Vec<Stage> {
            events.iter().filter(|e| e.trace_id == id).map(|e| e.stage).collect()
        };
        for id in [4242, a.trace_id, b.trace_id] {
            let stages = stages_of(id);
            for want in [Stage::Admit, Stage::Route, Stage::Queue, Stage::Respond] {
                assert!(stages.contains(&want), "trace {id} missing {want:?}: {stages:?}");
            }
        }
        // The wire payload groups them into one tree per request.
        let trees = svc.trace_json(10);
        let arr =
            trees.get("traces").and_then(|v| v.as_arr()).expect("trace_json has a traces array");
        assert!(arr.len() >= 3, "expected ≥ 3 span trees: {trees:?}");
        svc.shutdown();
    }

    #[test]
    fn step_level_traces_emit_model_and_solver_spans() {
        let spec = DatasetSpec::Cifar10Like;
        let gm = Arc::new(dataset(spec));
        let classes = (0..spec.n_classes()).map(|c| spec.class_components(c)).collect();
        let cfg = ServerConfig {
            workers: 1,
            queue_cap: 16,
            trace: crate::trace::TraceLevel::Steps,
            ..Default::default()
        };
        let svc = Service::start(
            cfg,
            ModelBackend::Analytic { gm, class_components: Arc::new(classes) },
        );
        let resp = svc.sample_blocking(SampleRequest {
            n: 1,
            steps: 5,
            seed: 9,
            ..Default::default()
        });
        assert!(resp.ok, "{:?}", resp.error);
        let events = svc.trace_events();
        let evals =
            events.iter().filter(|e| e.stage == Stage::ModelEval).count();
        let solves =
            events.iter().filter(|e| e.stage == Stage::SolverStep).count();
        assert_eq!(evals, 5, "one model_eval span per step: {events:?}");
        assert_eq!(evals, solves, "model_eval/solver_step come in pairs");
        // Off silences span recording entirely (digests stay on).
        let cfg_off = ServerConfig {
            workers: 1,
            queue_cap: 16,
            trace: crate::trace::TraceLevel::Off,
            ..Default::default()
        };
        let spec = DatasetSpec::Cifar10Like;
        let gm = Arc::new(dataset(spec));
        let classes = (0..spec.n_classes()).map(|c| spec.class_components(c)).collect();
        let svc_off = Service::start(
            cfg_off,
            ModelBackend::Analytic { gm, class_components: Arc::new(classes) },
        );
        let r = svc_off.sample_blocking(SampleRequest { n: 1, steps: 5, seed: 9, ..Default::default() });
        assert!(r.ok);
        assert!(svc_off.trace_events().is_empty(), "trace=off must record nothing");
        assert!(r.trace_id != 0, "ids are minted even with spans off");
        assert_eq!(r.model_eval_us + r.solver_us, r.compute_us);
        svc_off.shutdown();
        svc.shutdown();
    }
}
