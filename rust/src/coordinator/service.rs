//! The sampling service: a bounded queue + worker pool running solver loops.
//!
//! Each worker pops a request and first tries the **batched plan path**:
//! requests whose batch key matches — same [`plan_key`] *and* same model
//! conditioning (class/guidance) — are pulled out of the queue into one
//! lockstep run ([`crate::solver::sample_batch_with_plan`]) that shares a
//! cached `Arc<SamplePlan>`, advances every member through the same
//! timestep grid, and evaluates the model backend **once per step** on the
//! stacked batch tensor. Each worker keeps one pooled
//! [`crate::solver::BatchWorkspace`] reused across runs, so steady-state
//! runs start without allocating. Batched output is bit-identical to
//! running each request alone (`tests/batch_equiv.rs`).
//!
//! The batch assembler is bounded by `ServerConfig::max_batch` total rows
//! and, optionally, lingers `ServerConfig::batch_linger_us` for more
//! same-key arrivals (0 = coalesce only what is already queued).
//!
//! Every method in the registry compiles to a plan, so **the entire
//! workload is plan-cached and batchable** — UniPC, DPM-Solver++ (multistep
//! and singlestep), DPM-Solver, DEIS, PNDM, and DDIM requests all group by
//! batch key with no special-casing. The solo reference path only serves
//! requests whose method string fails admission parsing (to produce the
//! error response). With the PJRT backend, concurrent workers' model
//! evaluations additionally coalesce inside the runtime executor —
//! step-level dynamic batching below this layer.

use super::metrics::Metrics;
use super::request::{SampleRequest, SampleResponse};
use crate::analytic::GaussianMixture;
use crate::config::ServerConfig;
use crate::rng::Rng;
use crate::runtime::{PjrtHandle, PjrtModel};
use crate::sched::VpLinear;
use crate::solver::unipc::CoeffVariant;
use crate::solver::{
    plan_key, sample, sample_batch_with_plan, BatchWorkspace, Model, Prediction,
    SampleOptions, SamplePlan,
};
use crate::tensor::Tensor;
use anyhow::{anyhow, Result};
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// What evaluates ε_θ for the service.
#[derive(Clone)]
pub enum ModelBackend {
    /// The learned model through the PJRT executor (production path).
    Pjrt(PjrtHandle),
    /// The analytic mixture (exact score; used for tests/benches and when
    /// no artifacts are available).
    Analytic {
        gm: Arc<GaussianMixture>,
        /// Component indices per class (classifier-free guidance support).
        class_components: Arc<Vec<Vec<usize>>>,
    },
}

impl ModelBackend {
    pub fn dim(&self) -> usize {
        match self {
            ModelBackend::Pjrt(h) => h.dim,
            ModelBackend::Analytic { gm, .. } => gm.dim,
        }
    }
}

/// Per-request model view over a backend.
struct RequestModel<'a> {
    backend: &'a ModelBackend,
    sched: &'a VpLinear,
    class: Option<usize>,
    guidance: Option<f64>,
    pjrt: Option<PjrtModel>,
}

impl<'a> RequestModel<'a> {
    fn new(backend: &'a ModelBackend, sched: &'a VpLinear, req: &SampleRequest) -> Self {
        let pjrt = match backend {
            ModelBackend::Pjrt(h) => {
                let mut m = PjrtModel::new(h.clone());
                if let Some(c) = req.class {
                    m = m.with_class(c, req.guidance);
                }
                Some(m)
            }
            ModelBackend::Analytic { .. } => None,
        };
        RequestModel { backend, sched, class: req.class, guidance: req.guidance, pjrt }
    }
}

impl Model for RequestModel<'_> {
    fn prediction(&self) -> Prediction {
        Prediction::Noise
    }

    fn eval(&self, x: &Tensor, t: f64) -> Tensor {
        match self.backend {
            ModelBackend::Pjrt(_) => self.pjrt.as_ref().unwrap().eval(x, t),
            ModelBackend::Analytic { gm, class_components } => {
                let subset = self.class.map(|c| class_components[c].as_slice());
                let cond = gm.eps_star(self.sched, x, t, subset);
                match (self.guidance, subset) {
                    (Some(s), Some(_)) if s != 0.0 => {
                        let uncond = gm.eps_star(self.sched, x, t, None);
                        Tensor::lincomb(1.0 + s, &cond, -s, &uncond)
                    }
                    _ => cond,
                }
            }
        }
    }

    fn dim(&self) -> usize {
        self.backend.dim()
    }
}

struct QueuedJob {
    req: SampleRequest,
    /// Fully-resolved solver options, derived once at admission (`None`
    /// only if the method string fails to parse, which admission already
    /// rejects — kept as an Option so the solo path can still produce the
    /// failure response).
    opts: Option<SampleOptions>,
    /// Batch key (plan key + model conditioning), derived once at admission
    /// so the assembler's queue scan is an allocation-free string compare.
    /// `None` routes the job to the solo reference path.
    batch_key: Option<String>,
    reply: mpsc::Sender<SampleResponse>,
    enqueued: Instant,
}

/// Distinct solver configs are few in practice; the cap only guards against
/// a hostile client cycling order schedules to grow the map unboundedly.
const PLAN_CACHE_CAP: usize = 256;

struct Inner {
    queue: Mutex<VecDeque<QueuedJob>>,
    cv: Condvar,
    cfg: ServerConfig,
    backend: ModelBackend,
    sched: VpLinear,
    metrics: Mutex<Metrics>,
    /// Shared sampling plans keyed by [`plan_key`]: concurrent workers
    /// serving identically-configured requests execute from one
    /// `Arc<SamplePlan>` instead of re-deriving coefficients per request.
    plans: Mutex<HashMap<String, Arc<SamplePlan>>>,
    shutdown: AtomicBool,
}

/// The running service (clone to share).
#[derive(Clone)]
pub struct Service {
    inner: Arc<Inner>,
}

impl Service {
    /// Start the worker pool.
    pub fn start(cfg: ServerConfig, backend: ModelBackend) -> Service {
        let inner = Arc::new(Inner {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            cfg,
            backend,
            sched: VpLinear::default(),
            metrics: Mutex::new(Metrics::default()),
            plans: Mutex::new(HashMap::new()),
            shutdown: AtomicBool::new(false),
        });
        for i in 0..inner.cfg.workers {
            let inner = Arc::clone(&inner);
            std::thread::Builder::new()
                .name(format!("sampler-{i}"))
                .spawn(move || worker_loop(inner))
                .expect("spawn sampler worker");
        }
        Service { inner }
    }

    /// Submit a request. Applies admission control: invalid requests and a
    /// full queue are rejected immediately (backpressure).
    pub fn submit(&self, req: SampleRequest) -> Result<mpsc::Receiver<SampleResponse>> {
        let mut metrics = self.inner.metrics.lock().unwrap();
        metrics.submitted += 1;
        if let Err(e) = req.validate(self.inner.cfg.max_batch) {
            metrics.rejected += 1;
            return Err(e);
        }
        drop(metrics);

        let (tx, rx) = mpsc::channel();
        let (opts, batch_key) = admission_setup(&self.inner, &req);
        {
            let mut q = self.inner.queue.lock().unwrap();
            if q.len() >= self.inner.cfg.queue_cap {
                self.inner.metrics.lock().unwrap().rejected += 1;
                return Err(anyhow!("queue full ({} pending)", q.len()));
            }
            q.push_back(QueuedJob { req, opts, batch_key, reply: tx, enqueued: Instant::now() });
        }
        // notify_all, not notify_one: a lingering batch assembler waits on
        // this same condvar and would otherwise swallow the only wakeup
        // meant for an idle worker, stranding a non-matching job for the
        // rest of the linger window.
        self.inner.cv.notify_all();
        Ok(rx)
    }

    /// Submit and wait for the result.
    pub fn sample_blocking(&self, req: SampleRequest) -> SampleResponse {
        match self.submit(req) {
            Ok(rx) => rx
                .recv()
                .unwrap_or_else(|_| SampleResponse::failure("worker dropped request".into())),
            Err(e) => SampleResponse::failure(format!("{e:#}")),
        }
    }

    pub fn metrics_json(&self) -> crate::json::Value {
        self.inner.metrics.lock().unwrap().snapshot_json()
    }

    pub fn pending(&self) -> usize {
        self.inner.queue.lock().unwrap().len()
    }

    pub fn dim(&self) -> usize {
        self.inner.backend.dim()
    }

    /// Stop the workers (queued jobs are drained first).
    pub fn shutdown(&self) {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        self.inner.cv.notify_all();
    }
}

fn worker_loop(inner: Arc<Inner>) {
    // One pooled workspace per worker, reused across every batched run it
    // executes (the `workspace_reuses` metric counts successful reuse).
    let mut scratch = BatchWorkspace::new();
    loop {
        let job = {
            let mut q = inner.queue.lock().unwrap();
            loop {
                if let Some(job) = q.pop_front() {
                    break job;
                }
                if inner.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                q = inner.cv.wait(q).unwrap();
            }
        };
        match batch_setup(&inner, &job) {
            Some((opts, plan, key)) => {
                let mut jobs = vec![job];
                gather_batch(&inner, &key, &mut jobs);
                execute_batch(&inner, &mut scratch, jobs, &opts, &plan);
            }
            None => execute_solo(&inner, job),
        }
    }
}

/// Resolve the batched-execution setup for a popped job from its
/// admission-time fields: the solver options, the shared cached plan, and
/// the batch key grouping requests able to run in one lockstep batch.
/// `None` routes the job to the solo reference path (unplannable method).
fn batch_setup(
    inner: &Inner,
    job: &QueuedJob,
) -> Option<(SampleOptions, Arc<SamplePlan>, String)> {
    let key = job.batch_key.clone()?;
    let opts = job.opts.clone()?;
    let plan = lookup_plan(inner, &opts)?;
    Some((opts, plan, key))
}

/// Model-conditioning suffix of the batch key: batch members share one
/// model view, so class and guidance must match exactly (guidance compared
/// by bits).
fn conditioning_key(req: &SampleRequest) -> String {
    format!("|class={:?}|g={:?}", req.class, req.guidance.map(f64::to_bits))
}

/// Admission-time resolution, done once per request ([`Service::submit`])
/// and stored on the queued job: the full solver options and, for
/// plannable configurations, the batch key. The batch key is `None` for
/// methods plans don't cover (they take the solo path).
fn admission_setup(
    inner: &Inner,
    req: &SampleRequest,
) -> (Option<SampleOptions>, Option<String>) {
    let opts = build_opts(inner, req).ok();
    let key = opts.as_ref().filter(|o| SamplePlan::supports(o)).map(|o| {
        format!("{}{}", plan_key(&inner.sched, o), conditioning_key(req))
    });
    (opts, key)
}

/// Pull queued jobs whose batch key matches `key` into `jobs`, bounded by
/// `max_batch` total rows. With a linger window configured, waits up to the
/// deadline for more same-key arrivals; with the default of 0 this is a
/// single opportunistic scan of what is already queued.
fn gather_batch(inner: &Inner, key: &str, jobs: &mut Vec<QueuedJob>) {
    let mut rows: usize = jobs.iter().map(|j| j.req.n).sum();
    if rows >= inner.cfg.max_batch {
        return;
    }
    let deadline = Instant::now() + Duration::from_micros(inner.cfg.batch_linger_us);
    let mut q = inner.queue.lock().unwrap();
    loop {
        let mut i = 0;
        while i < q.len() {
            if rows + q[i].req.n <= inner.cfg.max_batch
                && q[i].batch_key.as_deref() == Some(key)
            {
                let j = q.remove(i).expect("index in range");
                rows += j.req.n;
                jobs.push(j);
                if rows >= inner.cfg.max_batch {
                    return;
                }
            } else {
                i += 1;
            }
        }
        if inner.shutdown.load(Ordering::SeqCst) {
            return;
        }
        let now = Instant::now();
        if now >= deadline {
            return;
        }
        // Jobs this batch can't absorb stay queued; they are picked up as
        // soon as any worker finishes its current run (at worst one linger
        // window from now). Deliberately no re-notify here: with every
        // waiter lingering, a notify would just bounce between assemblers
        // in a busy loop for the rest of the window.
        let (guard, _timeout) = inner.cv.wait_timeout(q, deadline - now).unwrap();
        q = guard;
    }
}

/// Execute a batch of same-key jobs in lockstep from the shared plan,
/// record per-request metrics, and reply to every member. A batch of one
/// still runs here: it reuses the worker's pooled workspace.
fn execute_batch(
    inner: &Inner,
    scratch: &mut BatchWorkspace,
    jobs: Vec<QueuedJob>,
    opts: &SampleOptions,
    plan: &SamplePlan,
) {
    let queue_times: Vec<Duration> = jobs.iter().map(|j| j.enqueued.elapsed()).collect();
    let started = Instant::now();
    // All members share conditioning (the batch key guarantees it), so one
    // model view serves the whole stacked batch.
    let model = RequestModel::new(&inner.backend, &inner.sched, &jobs[0].req);
    let dim = model.dim();
    let inits: Vec<Tensor> = jobs
        .iter()
        .map(|j| Rng::seed_from(j.req.seed).normal_tensor(&[j.req.n, dim]))
        .collect();
    let refs: Vec<&Tensor> = inits.iter().collect();
    let reuses_before = scratch.reuses();
    let results = sample_batch_with_plan(&model, &inner.sched, &refs, opts, plan, scratch);
    let compute_time = started.elapsed();

    let mut m = inner.metrics.lock().unwrap();
    // The leader's lookup_plan counted its own hit/build; followers were
    // absorbed without a lookup but are equally served from the cached
    // plan, so count them as hits to keep plan_hits per-request.
    m.plan_hits += jobs.len() as u64 - 1;
    m.record_batch(jobs.len(), scratch.reuses() - reuses_before);
    for (job, (r, qt)) in jobs.iter().zip(results.iter().zip(&queue_times)) {
        m.record_completion(job.req.n, r.nfe, *qt, compute_time);
    }
    drop(m);

    for (job, (r, qt)) in jobs.into_iter().zip(results.into_iter().zip(queue_times)) {
        let resp = SampleResponse {
            ok: true,
            error: None,
            nfe: r.nfe,
            queue_us: qt.as_micros() as u64,
            compute_us: compute_time.as_micros() as u64,
            samples: job.req.return_samples.then(|| r.x.data().to_vec()),
            dim,
        };
        let _ = job.reply.send(resp);
    }
}

/// The solo path: unplannable methods and parse failures.
fn execute_solo(inner: &Inner, job: QueuedJob) {
    let queue_time = job.enqueued.elapsed();
    let started = Instant::now();
    let resp = run_request(inner, &job.req, job.opts.as_ref());
    let compute_time = started.elapsed();

    let mut m = inner.metrics.lock().unwrap();
    match &resp {
        r if r.ok => m.record_completion(job.req.n, r.nfe, queue_time, compute_time),
        _ => m.failed += 1,
    }
    drop(m);

    let mut resp = resp;
    resp.queue_us = queue_time.as_micros() as u64;
    resp.compute_us = compute_time.as_micros() as u64;
    let _ = job.reply.send(resp);
}

/// Fetch (or build and cache) the shared plan for this solver config.
/// Returns `None` for configurations plans don't cover; those run the
/// reference loop.
fn lookup_plan(inner: &Inner, opts: &SampleOptions) -> Option<Arc<SamplePlan>> {
    if !SamplePlan::supports(opts) {
        return None;
    }
    let key = plan_key(&inner.sched, opts);
    {
        let plans = inner.plans.lock().unwrap();
        if let Some(p) = plans.get(&key) {
            let p = Arc::clone(p);
            drop(plans);
            inner.metrics.lock().unwrap().plan_hits += 1;
            return Some(p);
        }
    }
    let built = Arc::new(SamplePlan::build(&inner.sched, opts)?);
    let (shared, inserted) = {
        let mut plans = inner.plans.lock().unwrap();
        // Two workers may race to build the same plan; keep the first so
        // later requests all share one allocation, and count the loser as
        // a hit (plan_builds = distinct configs actually cached). Only a
        // genuinely new config evicts: a lost race must not shrink the
        // cache.
        if let Some(p) = plans.get(&key) {
            (Arc::clone(p), false)
        } else {
            if plans.len() >= PLAN_CACHE_CAP {
                // Evict one arbitrary entry: bounds memory without dumping
                // every hot plan the way a wholesale clear would under a
                // client churning distinct schedules.
                if let Some(stale) = plans.keys().next().cloned() {
                    plans.remove(&stale);
                }
            }
            plans.insert(key, Arc::clone(&built));
            (built, true)
        }
    };
    let mut m = inner.metrics.lock().unwrap();
    if inserted {
        m.plan_builds += 1;
    } else {
        m.plan_hits += 1;
    }
    drop(m);
    Some(shared)
}

/// Resolve a request's full solver options against the server defaults.
fn build_opts(inner: &Inner, req: &SampleRequest) -> Result<SampleOptions> {
    let method = req.parsed_method()?;
    let mut opts = SampleOptions::new(method, req.steps);
    opts.spacing = inner.cfg.spacing;
    opts.t_start = inner.cfg.t_start;
    opts.t_end = inner.cfg.t_end;
    if req.unic {
        // UniC inherits the base method's coefficient variant when the base
        // is UniP (UniPC proper); B₂ otherwise.
        let variant = match &opts.method {
            crate::solver::Method::UniP { variant, .. } => *variant,
            _ => CoeffVariant::Bh(crate::numerics::vandermonde::BFunction::Bh2),
        };
        opts = opts.with_unic(variant, false);
    }
    Ok(opts)
}

fn run_request(
    inner: &Inner,
    req: &SampleRequest,
    opts: Option<&SampleOptions>,
) -> SampleResponse {
    // `opts` is the admission-time resolution; absent means the method
    // failed to parse, so re-run the build to produce the error message.
    let opts = match opts {
        Some(o) => o.clone(),
        None => match build_opts(inner, req) {
            Ok(o) => o,
            Err(e) => return SampleResponse::failure(format!("{e:#}")),
        },
    };
    let model = RequestModel::new(&inner.backend, &inner.sched, req);
    let dim = model.dim();

    let mut rng = Rng::seed_from(req.seed);
    let x_t = rng.normal_tensor(&[req.n, dim]);
    // Plannable configs took the batched path; this runs the rest.
    let result = sample(&model, &inner.sched, &x_t, &opts);

    SampleResponse {
        ok: true,
        error: None,
        nfe: result.nfe,
        queue_us: 0,
        compute_us: 0,
        samples: req.return_samples.then(|| result.x.data().to_vec()),
        dim,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytic::datasets::{dataset, DatasetSpec};

    fn analytic_service(workers: usize, queue_cap: usize) -> Service {
        let spec = DatasetSpec::Cifar10Like;
        let gm = Arc::new(dataset(spec));
        let classes = (0..spec.n_classes()).map(|c| spec.class_components(c)).collect();
        let mut cfg = ServerConfig { workers, queue_cap, ..Default::default() };
        cfg.default_steps = 5;
        Service::start(
            cfg,
            ModelBackend::Analytic { gm, class_components: Arc::new(classes) },
        )
    }

    #[test]
    fn sample_roundtrip_deterministic() {
        let svc = analytic_service(2, 16);
        let req = SampleRequest { n: 3, steps: 6, seed: 42, ..Default::default() };
        let a = svc.sample_blocking(req.clone());
        let b = svc.sample_blocking(req);
        assert!(a.ok, "{:?}", a.error);
        assert_eq!(a.nfe, 6);
        assert_eq!(a.samples, b.samples, "same seed ⇒ same samples");
        assert_eq!(a.samples.as_ref().unwrap().len(), 3 * svc.dim());
        svc.shutdown();
    }

    #[test]
    fn invalid_requests_rejected() {
        let svc = analytic_service(1, 4);
        let bad = SampleRequest { n: 0, ..Default::default() };
        let r = svc.sample_blocking(bad);
        assert!(!r.ok);
        let bad2 = SampleRequest { method: "nope".into(), ..Default::default() };
        assert!(!svc.sample_blocking(bad2).ok);
        let m = svc.metrics_json();
        assert_eq!(m.get("rejected").unwrap().as_f64(), Some(2.0));
        svc.shutdown();
    }

    #[test]
    fn guided_requests_differ_from_unconditional() {
        let svc = analytic_service(2, 16);
        let base = SampleRequest { n: 2, steps: 5, seed: 7, ..Default::default() };
        let uncond = svc.sample_blocking(base.clone());
        let guided = svc.sample_blocking(SampleRequest {
            class: Some(1),
            guidance: Some(4.0),
            ..base
        });
        assert!(uncond.ok && guided.ok);
        assert_ne!(uncond.samples, guided.samples);
        svc.shutdown();
    }

    #[test]
    fn concurrent_load_all_complete() {
        let svc = analytic_service(4, 64);
        let handles: Vec<_> = (0..16)
            .map(|i| {
                let svc = svc.clone();
                std::thread::spawn(move || {
                    svc.sample_blocking(SampleRequest {
                        n: 2,
                        steps: 5,
                        seed: i,
                        return_samples: false,
                        ..Default::default()
                    })
                })
            })
            .collect();
        for h in handles {
            assert!(h.join().unwrap().ok);
        }
        let m = svc.metrics_json();
        assert_eq!(m.get("completed").unwrap().as_f64(), Some(16.0));
        svc.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // 1 worker, tiny queue, slow-ish requests: eventually rejects.
        let svc = analytic_service(1, 2);
        let mut rejected = 0;
        let mut receivers = Vec::new();
        for i in 0..20 {
            match svc.submit(SampleRequest {
                n: 4,
                steps: 40,
                seed: i,
                return_samples: false,
                ..Default::default()
            }) {
                Ok(rx) => receivers.push(rx),
                Err(_) => rejected += 1,
            }
        }
        assert!(rejected > 0, "queue cap must reject under overload");
        for rx in receivers {
            let _ = rx.recv();
        }
        svc.shutdown();
    }

    #[test]
    fn plan_cache_shared_across_same_config_requests() {
        let svc = analytic_service(2, 16);
        let req = SampleRequest { n: 2, steps: 6, seed: 1, ..Default::default() };
        assert!(svc.sample_blocking(req.clone()).ok);
        // Same solver config, different seed: must hit the cached plan.
        assert!(svc.sample_blocking(SampleRequest { seed: 2, ..req.clone() }).ok);
        let m = svc.metrics_json();
        assert_eq!(m.get("plan_builds").unwrap().as_f64(), Some(1.0));
        assert_eq!(m.get("plan_hits").unwrap().as_f64(), Some(1.0));
        // A different config builds its own plan.
        assert!(svc.sample_blocking(SampleRequest { steps: 7, seed: 3, ..req }).ok);
        let m = svc.metrics_json();
        assert_eq!(m.get("plan_builds").unwrap().as_f64(), Some(2.0));
        assert_eq!(m.get("plan_hits").unwrap().as_f64(), Some(1.0));
        // Non-UniPC methods are plan-cached too (the whole zoo compiles):
        // the first dpmpp-2m request builds, the second hits.
        let baseline = SampleRequest {
            method: "dpmpp-2m".into(),
            unic: false,
            seed: 4,
            ..Default::default()
        };
        assert!(svc.sample_blocking(baseline.clone()).ok);
        assert!(svc.sample_blocking(SampleRequest { seed: 5, ..baseline }).ok);
        let m = svc.metrics_json();
        assert_eq!(m.get("plan_builds").unwrap().as_f64(), Some(3.0));
        assert_eq!(m.get("plan_hits").unwrap().as_f64(), Some(2.0));
        svc.shutdown();
    }

    #[test]
    fn batched_execution_matches_solo_and_counts_metrics() {
        // One worker with a generous linger window: rapid-fire same-config
        // submissions coalesce into a lockstep batched run; the serialized
        // first pass runs each request as a batch of one. Both paths must
        // produce bit-identical samples.
        let spec = DatasetSpec::Cifar10Like;
        let gm = Arc::new(dataset(spec));
        let classes = (0..spec.n_classes()).map(|c| spec.class_components(c)).collect();
        let cfg = ServerConfig {
            workers: 1,
            queue_cap: 64,
            batch_linger_us: 50_000,
            ..Default::default()
        };
        let svc = Service::start(
            cfg,
            ModelBackend::Analytic { gm, class_components: Arc::new(classes) },
        );
        let reqs: Vec<SampleRequest> = (0..6)
            .map(|i| SampleRequest { n: 2, steps: 5, seed: i, ..Default::default() })
            .collect();
        let solo: Vec<Vec<f64>> = reqs
            .iter()
            .map(|r| svc.sample_blocking(r.clone()).samples.unwrap())
            .collect();
        let rxs: Vec<_> = reqs.iter().map(|r| svc.submit(r.clone()).unwrap()).collect();
        let batched: Vec<Vec<f64>> = rxs
            .into_iter()
            .map(|rx| rx.recv().unwrap().samples.unwrap())
            .collect();
        assert_eq!(solo, batched, "batched execution must be bit-identical to solo");

        let m = svc.metrics_json();
        assert_eq!(m.get("completed").unwrap().as_f64(), Some(12.0));
        assert!(
            m.get("batched_runs").unwrap().as_f64().unwrap() >= 1.0,
            "concurrent same-config requests must coalesce: {m:?}"
        );
        assert!(
            m.get("workspace_reuses").unwrap().as_f64().unwrap() >= 1.0,
            "per-worker workspace must be reused across runs: {m:?}"
        );
        svc.shutdown();
    }

    #[test]
    fn methods_dispatch_through_service() {
        let svc = analytic_service(2, 16);
        for method in ["ddim", "dpmpp-2m", "dpmpp-3m", "unipc-2-bh1", "pndm", "deis-2"] {
            let r = svc.sample_blocking(SampleRequest {
                n: 1,
                steps: 6,
                method: method.into(),
                unic: false,
                seed: 1,
                ..Default::default()
            });
            assert!(r.ok, "{method}: {:?}", r.error);
            assert!(r.samples.unwrap().iter().all(|v| v.is_finite()), "{method}");
        }
        svc.shutdown();
    }
}
