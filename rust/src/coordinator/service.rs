//! The sampling service: a bounded queue + supervised worker pool running
//! solver loops, with fault isolation around every execution.
//!
//! Each worker pops a request and first tries the **batched plan path**:
//! requests whose batch key matches — same [`plan_key`] *and* same model
//! conditioning (class/guidance) — are pulled out of the queue into one
//! lockstep run ([`crate::solver::sample_batch_with_plan`]) that shares a
//! cached `Arc<SamplePlan>`, advances every member through the same
//! timestep grid, and evaluates the model backend **once per step** on the
//! stacked batch tensor. Each worker keeps one pooled
//! [`crate::solver::BatchWorkspace`] reused across runs, so steady-state
//! runs start without allocating. Batched output is bit-identical to
//! running each request alone (`tests/batch_equiv.rs`).
//!
//! The batch assembler is bounded by `ServerConfig::max_batch` total rows
//! and, optionally, lingers `ServerConfig::batch_linger_us` for more
//! same-key arrivals (0 = coalesce only what is already queued) — never
//! past the earliest member deadline.
//!
//! **Fault tolerance.** Execution is wrapped in `catch_unwind`, so a panic
//! in a kernel or backend becomes a typed [`FailureKind::WorkerPanic`]
//! response for exactly the affected requests instead of a hung receiver.
//! A worker that caught a panic retires (its pooled workspace may be
//! corrupt); a supervisor guard respawns a replacement, keeping the pool
//! size invariant (`worker_restarts` counts this). A panic mid-batch
//! quarantines the cohort: every member is re-run solo (`batch_retries`),
//! so only the actual culprit fails and the rest stay bit-identical to a
//! fault-free run. Batched output is finiteness-checked per member on the
//! stacked tensor ([`Tensor::rows_finite`]); NaN/Inf rows fail only the
//! owning member ([`FailureKind::NonFiniteOutput`], `quarantined_members`)
//! because every kernel in the planned path is row-independent.
//!
//! **Deadlines.** Each request resolves a deadline at admission
//! (`deadline_ms`, defaulting to `ServerConfig::default_deadline_ms`; 0
//! disables). Jobs still queued past their deadline are shed at dequeue
//! with a typed [`FailureKind::DeadlineExceeded`] response and are never
//! executed.
//!
//! Every method in the registry compiles to a plan, so **the entire
//! workload is plan-cached and batchable** — UniPC, DPM-Solver++ (multistep
//! and singlestep), DPM-Solver, DEIS, PNDM, and DDIM requests all group by
//! batch key with no special-casing. The solo reference path only serves
//! requests whose method string fails admission parsing (to produce the
//! error response). With the PJRT backend, concurrent workers' model
//! evaluations additionally coalesce inside the runtime executor —
//! step-level dynamic batching below this layer.

use super::metrics::Metrics;
use super::request::{FailureKind, SampleRequest, SampleResponse};
use crate::analytic::GaussianMixture;
use crate::config::ServerConfig;
use crate::rng::Rng;
use crate::runtime::{PjrtHandle, PjrtModel};
use crate::sched::VpLinear;
use crate::solver::unipc::CoeffVariant;
use crate::solver::{
    plan_key, sample, sample_batch_with_plan, BatchWorkspace, Model, Prediction,
    SampleOptions, SamplePlan,
};
use crate::tensor::Tensor;
use std::any::Any;
use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Fault-injection settings for [`ModelBackend::Chaos`]: a seeded,
/// deterministic fault stream drawn once per model evaluation. Each eval
/// independently draws a latency spike, a panic, and a NaN'd output row, in
/// that order, so a given seed produces the same fault schedule regardless
/// of which faults actually fire.
#[derive(Clone, Copy, Debug)]
pub struct ChaosConfig {
    /// Seed for the fault stream (shared across all evals of this backend).
    pub seed: u64,
    /// Probability an eval panics (after any latency spike).
    pub panic_rate: f64,
    /// Probability an eval NaNs one row of its output.
    pub nan_rate: f64,
    /// Probability an eval sleeps `latency_us` first.
    pub latency_rate: f64,
    pub latency_us: u64,
}

/// What evaluates ε_θ for the service.
#[derive(Clone)]
pub enum ModelBackend {
    /// The learned model through the PJRT executor (production path).
    Pjrt(PjrtHandle),
    /// The analytic mixture (exact score; used for tests/benches and when
    /// no artifacts are available).
    Analytic {
        gm: Arc<GaussianMixture>,
        /// Component indices per class (classifier-free guidance support).
        class_components: Arc<Vec<Vec<usize>>>,
    },
    /// A fault-injecting decorator around another backend: panics, NaN
    /// rows, and latency spikes on a seeded deterministic schedule. Powers
    /// the chaos suite (`tests/fault_injection.rs`) and the serving bench's
    /// chaos ablation.
    Chaos {
        inner: Box<ModelBackend>,
        cfg: ChaosConfig,
        /// One shared fault stream: concurrent workers draw from the same
        /// seeded sequence, keeping the total fault mix at the configured
        /// rates regardless of interleaving.
        faults: Arc<Mutex<Rng>>,
    },
}

impl ModelBackend {
    pub fn dim(&self) -> usize {
        match self {
            ModelBackend::Pjrt(h) => h.dim,
            ModelBackend::Analytic { gm, .. } => gm.dim,
            ModelBackend::Chaos { inner, .. } => inner.dim(),
        }
    }

    /// Wrap a backend with seeded fault injection.
    pub fn chaos(inner: ModelBackend, cfg: ChaosConfig) -> ModelBackend {
        ModelBackend::Chaos {
            inner: Box::new(inner),
            faults: Arc::new(Mutex::new(Rng::seed_from(cfg.seed))),
            cfg,
        }
    }
}

/// Peel chaos decorators off a backend to reach the real evaluator.
fn base_backend(b: &ModelBackend) -> &ModelBackend {
    match b {
        ModelBackend::Chaos { inner, .. } => base_backend(inner),
        other => other,
    }
}

/// Install (once, process-wide) a panic hook that swallows the backtrace
/// noise of chaos-injected panics while delegating every real panic to the
/// previous hook. Call from chaos tests/benches before the first fault.
pub fn silence_injected_panics() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        let default = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let payload = info.payload();
            let msg = payload
                .downcast_ref::<&str>()
                .copied()
                .or_else(|| payload.downcast_ref::<String>().map(String::as_str));
            if !msg.is_some_and(|s| s.contains("chaos: injected")) {
                default(info);
            }
        }));
    });
}

/// Per-request model view over a backend.
struct RequestModel<'a> {
    backend: &'a ModelBackend,
    sched: &'a VpLinear,
    class: Option<usize>,
    guidance: Option<f64>,
    pjrt: Option<PjrtModel>,
}

impl<'a> RequestModel<'a> {
    fn new(backend: &'a ModelBackend, sched: &'a VpLinear, req: &SampleRequest) -> Self {
        let pjrt = match base_backend(backend) {
            ModelBackend::Pjrt(h) => {
                let mut m = PjrtModel::new(h.clone());
                if let Some(c) = req.class {
                    m = m.with_class(c, req.guidance);
                }
                Some(m)
            }
            _ => None,
        };
        RequestModel { backend, sched, class: req.class, guidance: req.guidance, pjrt }
    }

    fn eval_backend(&self, backend: &ModelBackend, x: &Tensor, t: f64) -> Tensor {
        match backend {
            ModelBackend::Pjrt(_) => self.pjrt.as_ref().unwrap().eval(x, t),
            ModelBackend::Analytic { gm, class_components } => {
                let subset = self.class.map(|c| class_components[c].as_slice());
                let cond = gm.eps_star(self.sched, x, t, subset);
                match (self.guidance, subset) {
                    (Some(s), Some(_)) if s != 0.0 => {
                        let uncond = gm.eps_star(self.sched, x, t, None);
                        Tensor::lincomb(1.0 + s, &cond, -s, &uncond)
                    }
                    _ => cond,
                }
            }
            ModelBackend::Chaos { inner, cfg, faults } => {
                // Draw the whole fault tuple in one lock scope — the same
                // number of draws per eval whether or not faults fire — and
                // release the lock before acting, so an injected panic can
                // never poison the shared fault stream.
                let (sleep, boom, nan_row) = {
                    let mut rng = faults.lock().unwrap();
                    let sleep = rng.uniform() < cfg.latency_rate;
                    let boom = rng.uniform() < cfg.panic_rate;
                    let nan = rng.uniform() < cfg.nan_rate;
                    let row = rng.below(x.batch().max(1));
                    (sleep, boom, nan.then_some(row))
                };
                if sleep {
                    std::thread::sleep(Duration::from_micros(cfg.latency_us));
                }
                if boom {
                    panic!("chaos: injected model panic");
                }
                let mut out = self.eval_backend(inner, x, t);
                if let Some(row) = nan_row {
                    if row < out.batch() {
                        for v in out.row_mut(row) {
                            *v = f64::NAN;
                        }
                    }
                }
                out
            }
        }
    }
}

impl Model for RequestModel<'_> {
    fn prediction(&self) -> Prediction {
        Prediction::Noise
    }

    fn eval(&self, x: &Tensor, t: f64) -> Tensor {
        self.eval_backend(self.backend, x, t)
    }

    fn dim(&self) -> usize {
        self.backend.dim()
    }
}

struct QueuedJob {
    req: SampleRequest,
    /// Fully-resolved solver options, derived once at admission (`None`
    /// only if the method string fails to parse, which admission already
    /// rejects — kept as an Option so the solo path can still produce the
    /// failure response).
    opts: Option<SampleOptions>,
    /// Batch key (plan key + model conditioning), derived once at admission
    /// so the assembler's queue scan is an allocation-free string compare.
    /// `None` routes the job to the solo reference path.
    batch_key: Option<String>,
    reply: mpsc::Sender<SampleResponse>,
    enqueued: Instant,
    /// Absolute deadline resolved at admission; `None` = no deadline.
    deadline: Option<Instant>,
}

/// Distinct solver configs are few in practice; the cap only guards against
/// a hostile client cycling order schedules to grow the map unboundedly.
const PLAN_CACHE_CAP: usize = 256;

/// Last-use LRU cache of compiled plans. A u64 logical clock stamps every
/// hit and insert; eviction removes the entry with the oldest stamp, so a
/// hot plan survives arbitrary churn of one-shot configs (the previous
/// arbitrary-eviction policy could dump the hottest plan).
struct PlanCache {
    cap: usize,
    clock: u64,
    map: HashMap<String, (Arc<SamplePlan>, u64)>,
}

impl PlanCache {
    fn new(cap: usize) -> PlanCache {
        PlanCache { cap: cap.max(1), clock: 0, map: HashMap::new() }
    }

    #[cfg(test)]
    fn len(&self) -> usize {
        self.map.len()
    }

    fn get(&mut self, key: &str) -> Option<Arc<SamplePlan>> {
        self.clock += 1;
        let clock = self.clock;
        self.map.get_mut(key).map(|e| {
            e.1 = clock;
            Arc::clone(&e.0)
        })
    }

    fn insert(&mut self, key: String, plan: Arc<SamplePlan>) {
        self.clock += 1;
        if self.map.len() >= self.cap && !self.map.contains_key(&key) {
            // O(n) scan is fine at this cap; eviction is rare by design.
            let victim = self.map.iter().min_by_key(|(_, v)| v.1).map(|(k, _)| k.clone());
            if let Some(k) = victim {
                self.map.remove(&k);
            }
        }
        self.map.insert(key, (plan, self.clock));
    }
}

struct Inner {
    queue: Mutex<VecDeque<QueuedJob>>,
    cv: Condvar,
    cfg: ServerConfig,
    backend: ModelBackend,
    sched: VpLinear,
    metrics: Mutex<Metrics>,
    /// Shared sampling plans keyed by [`plan_key`]: concurrent workers
    /// serving identically-configured requests execute from one
    /// `Arc<SamplePlan>` instead of re-deriving coefficients per request.
    plans: Mutex<PlanCache>,
    shutdown: AtomicBool,
    /// Live worker handles, joined by [`Service::shutdown`]. The supervisor
    /// pushes replacements here as it respawns panicked workers.
    handles: Mutex<Vec<JoinHandle<()>>>,
}

/// The running service (clone to share).
#[derive(Clone)]
pub struct Service {
    inner: Arc<Inner>,
}

impl Service {
    /// Start the worker pool.
    pub fn start(cfg: ServerConfig, backend: ModelBackend) -> Service {
        let inner = Arc::new(Inner {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            cfg,
            backend,
            sched: VpLinear::default(),
            metrics: Mutex::new(Metrics::default()),
            plans: Mutex::new(PlanCache::new(PLAN_CACHE_CAP)),
            shutdown: AtomicBool::new(false),
            handles: Mutex::new(Vec::new()),
        });
        for i in 0..inner.cfg.workers {
            spawn_worker(&inner, i);
        }
        Service { inner }
    }

    /// Submit a request. Applies admission control: invalid requests, a full
    /// queue (backpressure), and a shut-down service are rejected
    /// immediately with the typed response they would otherwise have
    /// received on the channel.
    pub fn submit(
        &self,
        req: SampleRequest,
    ) -> Result<mpsc::Receiver<SampleResponse>, SampleResponse> {
        {
            let mut metrics = self.inner.metrics.lock().unwrap();
            metrics.submitted += 1;
            if self.inner.shutdown.load(Ordering::SeqCst) {
                metrics.rejected += 1;
                metrics.failures_by_kind[FailureKind::BackendError.index()] += 1;
                return Err(SampleResponse::failure(
                    FailureKind::BackendError,
                    "service is shut down".into(),
                ));
            }
            if let Err(e) = req.validate(self.inner.cfg.max_batch) {
                metrics.rejected += 1;
                metrics.failures_by_kind[FailureKind::InvalidRequest.index()] += 1;
                return Err(SampleResponse::failure(
                    FailureKind::InvalidRequest,
                    format!("{e:#}"),
                ));
            }
        }

        let (tx, rx) = mpsc::channel();
        let (opts, batch_key) = admission_setup(&self.inner, &req);
        let enqueued = Instant::now();
        let deadline = resolve_deadline_ms(&self.inner.cfg, &req)
            .map(|ms| enqueued + Duration::from_millis(ms));
        {
            let mut q = self.inner.queue.lock().unwrap();
            if q.len() >= self.inner.cfg.queue_cap {
                let pending = q.len();
                drop(q);
                let mut metrics = self.inner.metrics.lock().unwrap();
                metrics.rejected += 1;
                metrics.failures_by_kind[FailureKind::QueueFull.index()] += 1;
                return Err(SampleResponse::failure(
                    FailureKind::QueueFull,
                    format!("queue full ({pending} pending)"),
                ));
            }
            q.push_back(QueuedJob { req, opts, batch_key, reply: tx, enqueued, deadline });
        }
        // notify_all, not notify_one: a lingering batch assembler waits on
        // this same condvar and would otherwise swallow the only wakeup
        // meant for an idle worker, stranding a non-matching job for the
        // rest of the linger window.
        self.inner.cv.notify_all();
        Ok(rx)
    }

    /// Submit and wait for the result. The wait itself is bounded by the
    /// request deadline (plus a grace window for a job admitted just inside
    /// its deadline to finish computing), so a stuck worker can't hang the
    /// caller.
    pub fn sample_blocking(&self, req: SampleRequest) -> SampleResponse {
        let deadline_ms = resolve_deadline_ms(&self.inner.cfg, &req);
        let rx = match self.submit(req) {
            Ok(rx) => rx,
            Err(resp) => return resp,
        };
        match deadline_ms {
            None => rx.recv().unwrap_or_else(|_| {
                SampleResponse::failure(FailureKind::WorkerPanic, "worker dropped request".into())
            }),
            Some(ms) => {
                let grace = Duration::from_millis(self.inner.cfg.drain_deadline_ms.max(1_000));
                match rx.recv_timeout(Duration::from_millis(ms) + grace) {
                    Ok(resp) => resp,
                    Err(mpsc::RecvTimeoutError::Timeout) => SampleResponse::failure(
                        FailureKind::DeadlineExceeded,
                        format!("no response within deadline ({ms} ms + grace)"),
                    ),
                    Err(mpsc::RecvTimeoutError::Disconnected) => SampleResponse::failure(
                        FailureKind::WorkerPanic,
                        "worker dropped request".into(),
                    ),
                }
            }
        }
    }

    pub fn metrics_json(&self) -> crate::json::Value {
        self.inner.metrics.lock().unwrap().snapshot_json()
    }

    pub fn pending(&self) -> usize {
        self.inner.queue.lock().unwrap().len()
    }

    pub fn dim(&self) -> usize {
        self.inner.backend.dim()
    }

    /// Number of live (not yet finished) worker threads. The supervisor
    /// keeps this at `cfg.workers`; a retiring thread may transiently still
    /// count while its replacement is already live.
    pub fn workers_alive(&self) -> usize {
        self.inner.handles.lock().unwrap().iter().filter(|h| !h.is_finished()).count()
    }

    /// Stop the pool: give workers `cfg.drain_deadline_ms` to drain the
    /// queue, shed whatever is left with typed responses (no receiver is
    /// ever left hanging), then join every worker. Idempotent.
    pub fn shutdown(&self) {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        self.inner.cv.notify_all();

        // Bounded drain: workers keep popping until the flag stops them at
        // an empty queue.
        let drain_until =
            Instant::now() + Duration::from_millis(self.inner.cfg.drain_deadline_ms);
        while Instant::now() < drain_until {
            if self.inner.queue.lock().unwrap().is_empty() {
                break;
            }
            self.inner.cv.notify_all();
            std::thread::sleep(Duration::from_millis(1));
        }

        // Shed stragglers with a typed response so every receiver resolves.
        let shed: Vec<QueuedJob> = {
            let mut q = self.inner.queue.lock().unwrap();
            q.drain(..).collect()
        };
        if !shed.is_empty() {
            let mut m = self.inner.metrics.lock().unwrap();
            for _ in &shed {
                m.record_failure(FailureKind::BackendError);
            }
        }
        for job in shed {
            let _ = job.reply.send(SampleResponse::failure(
                FailureKind::BackendError,
                "service shut down before execution".into(),
            ));
        }

        // Join the pool. The shutdown flag is checked under no lock, so a
        // worker can race past its check and block on the condvar after our
        // notify — keep re-notifying until each thread actually exits
        // (spin-join) rather than risking a lost-wakeup deadlock.
        loop {
            let handle = {
                let mut handles = self.inner.handles.lock().unwrap();
                handles.pop()
            };
            let h = match handle {
                Some(h) => h,
                None => break,
            };
            while !h.is_finished() {
                self.inner.cv.notify_all();
                std::thread::sleep(Duration::from_millis(1));
            }
            if let Err(p) = h.join() {
                std::panic::resume_unwind(p);
            }
        }
    }
}

/// Resolve a request's effective deadline in ms: per-request override, else
/// the server default; 0 from either source disables it.
fn resolve_deadline_ms(cfg: &ServerConfig, req: &SampleRequest) -> Option<u64> {
    let ms = req.deadline_ms.unwrap_or(cfg.default_deadline_ms);
    if ms == 0 {
        None
    } else {
        Some(ms)
    }
}

/// Spawn one worker and record its handle (pruning handles of threads that
/// already exited, so the vec stays bounded under churn).
fn spawn_worker(inner: &Arc<Inner>, id: usize) {
    let arc = Arc::clone(inner);
    let handle = std::thread::Builder::new()
        .name(format!("sampler-{id}"))
        .spawn(move || worker_loop(arc, id))
        .expect("spawn sampler worker");
    let mut handles = inner.handles.lock().unwrap();
    handles.retain(|h| !h.is_finished());
    handles.push(handle);
}

/// Supervision: when a worker retires (caught panic ⇒ possibly-corrupt
/// pooled state) or unwinds past the loop entirely, its drop respawns a
/// replacement so the pool size is an invariant. No respawn once shutdown
/// has begun.
struct RespawnGuard {
    inner: Arc<Inner>,
    id: usize,
    retire: bool,
}

impl Drop for RespawnGuard {
    fn drop(&mut self) {
        if self.inner.shutdown.load(Ordering::SeqCst) {
            return;
        }
        if self.retire || std::thread::panicking() {
            // `if let Ok`: never double-panic in a Drop over a metrics lock
            // that the panicking thread might have poisoned.
            if let Ok(mut m) = self.inner.metrics.lock() {
                m.worker_restarts += 1;
            }
            spawn_worker(&self.inner, self.id);
        }
    }
}

fn worker_loop(inner: Arc<Inner>, id: usize) {
    let mut guard = RespawnGuard { inner: Arc::clone(&inner), id, retire: false };
    // One pooled workspace per worker, reused across every batched run it
    // executes (the `workspace_reuses` metric counts successful reuse).
    let mut scratch = BatchWorkspace::new();
    loop {
        let job = {
            let mut q = inner.queue.lock().unwrap();
            loop {
                if let Some(job) = q.pop_front() {
                    break job;
                }
                if inner.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                q = inner.cv.wait(q).unwrap();
            }
        };
        let job = match shed_if_expired(&inner, job) {
            Some(j) => j,
            None => continue,
        };
        let tainted = match batch_setup(&inner, &job) {
            Some((opts, plan, key)) => {
                let mut jobs = vec![job];
                gather_batch(&inner, &key, &mut jobs);
                execute_batch(&inner, &mut scratch, jobs, &opts, &plan)
            }
            None => execute_solo(&inner, job),
        };
        if tainted {
            // A caught panic may have left the pooled workspace (or any
            // worker-local state) inconsistent: retire fail-stop and let
            // the supervisor bring up a clean replacement.
            guard.retire = true;
            return;
        }
    }
}

/// Shed `job` with a typed `DeadlineExceeded` response if its deadline has
/// passed; expired jobs are never executed.
fn shed_if_expired(inner: &Inner, job: QueuedJob) -> Option<QueuedJob> {
    let expired = job.deadline.is_some_and(|d| Instant::now() >= d);
    if expired {
        shed_expired(inner, job);
        None
    } else {
        Some(job)
    }
}

fn shed_expired(inner: &Inner, job: QueuedJob) {
    let waited = job.enqueued.elapsed();
    inner.metrics.lock().unwrap().record_failure(FailureKind::DeadlineExceeded);
    let mut resp = SampleResponse::failure(
        FailureKind::DeadlineExceeded,
        format!("deadline exceeded after {}us in queue", waited.as_micros()),
    );
    resp.queue_us = waited.as_micros() as u64;
    let _ = job.reply.send(resp);
}

/// Resolve the batched-execution setup for a popped job from its
/// admission-time fields: the solver options, the shared cached plan, and
/// the batch key grouping requests able to run in one lockstep batch.
/// `None` routes the job to the solo reference path (unplannable method).
fn batch_setup(
    inner: &Inner,
    job: &QueuedJob,
) -> Option<(SampleOptions, Arc<SamplePlan>, String)> {
    let key = job.batch_key.clone()?;
    let opts = job.opts.clone()?;
    let plan = lookup_plan(inner, &opts)?;
    Some((opts, plan, key))
}

/// Model-conditioning suffix of the batch key: batch members share one
/// model view, so class and guidance must match exactly (guidance compared
/// by bits).
fn conditioning_key(req: &SampleRequest) -> String {
    format!("|class={:?}|g={:?}", req.class, req.guidance.map(f64::to_bits))
}

/// Admission-time resolution, done once per request ([`Service::submit`])
/// and stored on the queued job: the full solver options and, for
/// plannable configurations, the batch key. The batch key is `None` for
/// methods plans don't cover (they take the solo path).
fn admission_setup(
    inner: &Inner,
    req: &SampleRequest,
) -> (Option<SampleOptions>, Option<String>) {
    let opts = build_opts(inner, req).ok();
    let key = opts.as_ref().filter(|o| SamplePlan::supports(o)).map(|o| {
        format!("{}{}", plan_key(&inner.sched, o), conditioning_key(req))
    });
    (opts, key)
}

/// Pull queued jobs whose batch key matches `key` into `jobs`, bounded by
/// `max_batch` total rows. With a linger window configured, waits up to the
/// deadline for more same-key arrivals; with the default of 0 this is a
/// single opportunistic scan of what is already queued. Expired same-key
/// jobs found during the scan are shed, not absorbed.
fn gather_batch(inner: &Inner, key: &str, jobs: &mut Vec<QueuedJob>) {
    let mut rows: usize = jobs.iter().map(|j| j.req.n).sum();
    if rows >= inner.cfg.max_batch {
        return;
    }
    let mut deadline = Instant::now() + Duration::from_micros(inner.cfg.batch_linger_us);
    // Never linger past a member's request deadline: waiting longer only
    // adds latency to a job that is already out of slack.
    for j in jobs.iter() {
        if let Some(d) = j.deadline {
            deadline = deadline.min(d);
        }
    }
    let mut q = inner.queue.lock().unwrap();
    loop {
        let mut i = 0;
        while i < q.len() {
            if q[i].batch_key.as_deref() == Some(key) {
                if q[i].deadline.is_some_and(|d| Instant::now() >= d) {
                    // Queue lock → metrics lock is the allowed order.
                    let j = q.remove(i).expect("index in range");
                    shed_expired(inner, j);
                    continue;
                }
                if rows + q[i].req.n <= inner.cfg.max_batch {
                    let j = q.remove(i).expect("index in range");
                    rows += j.req.n;
                    jobs.push(j);
                    if let Some(d) = jobs.last().and_then(|j| j.deadline) {
                        deadline = deadline.min(d);
                    }
                    if rows >= inner.cfg.max_batch {
                        return;
                    }
                    continue;
                }
            }
            i += 1;
        }
        if inner.shutdown.load(Ordering::SeqCst) {
            return;
        }
        let now = Instant::now();
        if now >= deadline {
            return;
        }
        // Jobs this batch can't absorb stay queued; they are picked up as
        // soon as any worker finishes its current run (at worst one linger
        // window from now). Deliberately no re-notify here: with every
        // waiter lingering, a notify would just bounce between assemblers
        // in a busy loop for the rest of the window.
        let (guard, _timeout) = inner.cv.wait_timeout(q, deadline - now).unwrap();
        q = guard;
    }
}

/// Best-effort stringification of a panic payload for the failure message.
fn panic_message(payload: &(dyn Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

/// Execute a batch of same-key jobs in lockstep from the shared plan,
/// record per-request metrics, and reply to every member. A batch of one
/// still runs here: it reuses the worker's pooled workspace.
///
/// Returns `true` if the run panicked (the worker must retire). On a
/// mid-batch panic the cohort is quarantined: every member is re-run solo,
/// so only the member whose evaluation actually faults fails and the rest
/// produce output bit-identical to a fault-free run (the solo path executes
/// the same plan). On a clean run, each member's output rows are checked
/// for finiteness on the stacked tensor; non-finite members fail
/// individually while their cohort completes.
fn execute_batch(
    inner: &Inner,
    scratch: &mut BatchWorkspace,
    jobs: Vec<QueuedJob>,
    opts: &SampleOptions,
    plan: &SamplePlan,
) -> bool {
    let queue_times: Vec<Duration> = jobs.iter().map(|j| j.enqueued.elapsed()).collect();
    let started = Instant::now();
    // All members share conditioning (the batch key guarantees it), so one
    // model view serves the whole stacked batch.
    let model = RequestModel::new(&inner.backend, &inner.sched, &jobs[0].req);
    let dim = model.dim();
    let inits: Vec<Tensor> = jobs
        .iter()
        .map(|j| Rng::seed_from(j.req.seed).normal_tensor(&[j.req.n, dim]))
        .collect();
    let refs: Vec<&Tensor> = inits.iter().collect();
    let reuses_before = scratch.reuses();
    let outcome = catch_unwind(AssertUnwindSafe(|| {
        sample_batch_with_plan(&model, &inner.sched, &refs, opts, plan, scratch)
    }));
    let compute_time = started.elapsed();

    let results = match outcome {
        Ok(results) => results,
        Err(payload) => {
            let msg = panic_message(payload.as_ref());
            if jobs.len() > 1 {
                // Quarantine: re-run every member solo so only the actual
                // culprit fails; the others stay bit-identical to a clean
                // run (solo executes the same plan).
                inner.metrics.lock().unwrap().batch_retries += jobs.len() as u64;
                for job in jobs {
                    let _ = execute_solo(inner, job);
                }
            } else {
                // A batch of one has no cohort to protect; fail it typed.
                let job = jobs.into_iter().next().expect("non-empty batch");
                let resp = SampleResponse::failure(
                    FailureKind::WorkerPanic,
                    format!("worker panicked during execution: {msg}"),
                );
                finish_solo(inner, job, resp, queue_times[0], compute_time);
            }
            return true;
        }
    };

    // Per-member finiteness on the stacked output: kernels in the planned
    // path are row-independent, so a NaN/Inf row can only have poisoned the
    // member that owns it — quarantine exactly those members.
    let finite: Vec<bool> = {
        let stacked = scratch.stacked();
        let mut row = 0usize;
        jobs.iter()
            .map(|j| {
                let ok = stacked.rows_finite(row, j.req.n);
                row += j.req.n;
                ok
            })
            .collect()
    };

    let mut m = inner.metrics.lock().unwrap();
    // The leader's lookup_plan counted its own hit/build; followers were
    // absorbed without a lookup but are equally served from the cached
    // plan, so count them as hits to keep plan_hits per-request.
    m.plan_hits += jobs.len() as u64 - 1;
    m.record_batch(jobs.len(), scratch.reuses() - reuses_before);
    for ((job, r), (qt, ok)) in
        jobs.iter().zip(results.iter()).zip(queue_times.iter().zip(&finite))
    {
        if *ok {
            m.record_completion(job.req.n, r.nfe, *qt, compute_time);
        } else {
            m.quarantined_members += 1;
            m.record_failure(FailureKind::NonFiniteOutput);
        }
    }
    drop(m);

    for ((job, r), (qt, ok)) in
        jobs.into_iter().zip(results).zip(queue_times.into_iter().zip(finite))
    {
        let mut resp = if ok {
            SampleResponse::success(
                r.nfe,
                job.req.return_samples.then(|| r.x.data().to_vec()),
                dim,
            )
        } else {
            let mut f = SampleResponse::failure(
                FailureKind::NonFiniteOutput,
                "solver produced non-finite output for this request".into(),
            );
            f.nfe = r.nfe;
            f.dim = dim;
            f
        };
        resp.queue_us = qt.as_micros() as u64;
        resp.compute_us = compute_time.as_micros() as u64;
        let _ = job.reply.send(resp);
    }
    false
}

/// The solo path: unplannable methods, parse failures, and quarantined
/// batch-member retries. Returns `true` if the run panicked (the worker
/// must retire).
fn execute_solo(inner: &Inner, job: QueuedJob) -> bool {
    let queue_time = job.enqueued.elapsed();
    let started = Instant::now();
    let outcome = catch_unwind(AssertUnwindSafe(|| {
        run_request(inner, &job.req, job.opts.as_ref())
    }));
    let compute_time = started.elapsed();
    match outcome {
        Ok(resp) => {
            finish_solo(inner, job, resp, queue_time, compute_time);
            false
        }
        Err(payload) => {
            let resp = SampleResponse::failure(
                FailureKind::WorkerPanic,
                format!(
                    "worker panicked during execution: {}",
                    panic_message(payload.as_ref())
                ),
            );
            finish_solo(inner, job, resp, queue_time, compute_time);
            true
        }
    }
}

/// Record metrics for a solo outcome, stamp latencies, and reply.
fn finish_solo(
    inner: &Inner,
    job: QueuedJob,
    mut resp: SampleResponse,
    queued: Duration,
    compute: Duration,
) {
    {
        let mut m = inner.metrics.lock().unwrap();
        match resp.kind {
            None => m.record_completion(job.req.n, resp.nfe, queued, compute),
            Some(k) => m.record_failure(k),
        }
    }
    resp.queue_us = queued.as_micros() as u64;
    resp.compute_us = compute.as_micros() as u64;
    let _ = job.reply.send(resp);
}

/// Fetch (or build and cache) the shared plan for this solver config.
/// Returns `None` for configurations plans don't cover; those run the
/// reference loop.
fn lookup_plan(inner: &Inner, opts: &SampleOptions) -> Option<Arc<SamplePlan>> {
    if !SamplePlan::supports(opts) {
        return None;
    }
    let key = plan_key(&inner.sched, opts);
    {
        let mut plans = inner.plans.lock().unwrap();
        if let Some(p) = plans.get(&key) {
            drop(plans);
            inner.metrics.lock().unwrap().plan_hits += 1;
            return Some(p);
        }
    }
    let built = Arc::new(SamplePlan::build(&inner.sched, opts)?);
    let (shared, inserted) = {
        let mut plans = inner.plans.lock().unwrap();
        // Two workers may race to build the same plan; keep the first so
        // later requests all share one allocation, and count the loser as
        // a hit (plan_builds = distinct configs actually cached). Only a
        // genuinely new config evicts: a lost race must not shrink the
        // cache.
        if let Some(p) = plans.get(&key) {
            (p, false)
        } else {
            plans.insert(key, Arc::clone(&built));
            (built, true)
        }
    };
    let mut m = inner.metrics.lock().unwrap();
    if inserted {
        m.plan_builds += 1;
    } else {
        m.plan_hits += 1;
    }
    drop(m);
    Some(shared)
}

/// Resolve a request's full solver options against the server defaults.
fn build_opts(inner: &Inner, req: &SampleRequest) -> anyhow::Result<SampleOptions> {
    let method = req.parsed_method()?;
    let mut opts = SampleOptions::new(method, req.steps);
    opts.spacing = inner.cfg.spacing;
    opts.t_start = inner.cfg.t_start;
    opts.t_end = inner.cfg.t_end;
    if req.unic {
        // UniC inherits the base method's coefficient variant when the base
        // is UniP (UniPC proper); B₂ otherwise.
        let variant = match &opts.method {
            crate::solver::Method::UniP { variant, .. } => *variant,
            _ => CoeffVariant::Bh(crate::numerics::vandermonde::BFunction::Bh2),
        };
        opts = opts.with_unic(variant, false);
    }
    Ok(opts)
}

fn run_request(
    inner: &Inner,
    req: &SampleRequest,
    opts: Option<&SampleOptions>,
) -> SampleResponse {
    // `opts` is the admission-time resolution; absent means the method
    // failed to parse, so re-run the build to produce the error message.
    let opts = match opts {
        Some(o) => o.clone(),
        None => match build_opts(inner, req) {
            Ok(o) => o,
            Err(e) => {
                return SampleResponse::failure(FailureKind::InvalidRequest, format!("{e:#}"))
            }
        },
    };
    let model = RequestModel::new(&inner.backend, &inner.sched, req);
    let dim = model.dim();

    let mut rng = Rng::seed_from(req.seed);
    let x_t = rng.normal_tensor(&[req.n, dim]);
    // Plannable configs take the planned path inside `sample` too, so a
    // quarantined batch member re-run here is bit-identical to its batch.
    let result = sample(&model, &inner.sched, &x_t, &opts);

    if !result.x.rows_finite(0, req.n) {
        let mut f = SampleResponse::failure(
            FailureKind::NonFiniteOutput,
            "solver produced non-finite output for this request".into(),
        );
        f.nfe = result.nfe;
        f.dim = dim;
        return f;
    }
    SampleResponse::success(
        result.nfe,
        req.return_samples.then(|| result.x.data().to_vec()),
        dim,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytic::datasets::{dataset, DatasetSpec};

    fn analytic_service(workers: usize, queue_cap: usize) -> Service {
        let spec = DatasetSpec::Cifar10Like;
        let gm = Arc::new(dataset(spec));
        let classes = (0..spec.n_classes()).map(|c| spec.class_components(c)).collect();
        let mut cfg = ServerConfig { workers, queue_cap, ..Default::default() };
        cfg.default_steps = 5;
        Service::start(
            cfg,
            ModelBackend::Analytic { gm, class_components: Arc::new(classes) },
        )
    }

    #[test]
    fn sample_roundtrip_deterministic() {
        let svc = analytic_service(2, 16);
        let req = SampleRequest { n: 3, steps: 6, seed: 42, ..Default::default() };
        let a = svc.sample_blocking(req.clone());
        let b = svc.sample_blocking(req);
        assert!(a.ok, "{:?}", a.error);
        assert_eq!(a.nfe, 6);
        assert_eq!(a.samples, b.samples, "same seed ⇒ same samples");
        assert_eq!(a.samples.as_ref().unwrap().len(), 3 * svc.dim());
        svc.shutdown();
    }

    #[test]
    fn invalid_requests_rejected() {
        let svc = analytic_service(1, 4);
        let bad = SampleRequest { n: 0, ..Default::default() };
        let r = svc.sample_blocking(bad);
        assert!(!r.ok);
        assert_eq!(r.kind, Some(FailureKind::InvalidRequest));
        let bad2 = SampleRequest { method: "nope".into(), ..Default::default() };
        assert!(!svc.sample_blocking(bad2).ok);
        let m = svc.metrics_json();
        assert_eq!(m.get("rejected").unwrap().as_f64(), Some(2.0));
        assert_eq!(m.get("invalid_request").unwrap().as_f64(), Some(2.0));
        svc.shutdown();
    }

    #[test]
    fn guided_requests_differ_from_unconditional() {
        let svc = analytic_service(2, 16);
        let base = SampleRequest { n: 2, steps: 5, seed: 7, ..Default::default() };
        let uncond = svc.sample_blocking(base.clone());
        let guided = svc.sample_blocking(SampleRequest {
            class: Some(1),
            guidance: Some(4.0),
            ..base
        });
        assert!(uncond.ok && guided.ok);
        assert_ne!(uncond.samples, guided.samples);
        svc.shutdown();
    }

    #[test]
    fn concurrent_load_all_complete() {
        let svc = analytic_service(4, 64);
        let handles: Vec<_> = (0..16)
            .map(|i| {
                let svc = svc.clone();
                std::thread::spawn(move || {
                    svc.sample_blocking(SampleRequest {
                        n: 2,
                        steps: 5,
                        seed: i,
                        return_samples: false,
                        ..Default::default()
                    })
                })
            })
            .collect();
        for h in handles {
            assert!(h.join().unwrap().ok);
        }
        let m = svc.metrics_json();
        assert_eq!(m.get("completed").unwrap().as_f64(), Some(16.0));
        svc.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // 1 worker, tiny queue, slow-ish requests: eventually rejects.
        let svc = analytic_service(1, 2);
        let mut rejected = 0;
        let mut receivers = Vec::new();
        for i in 0..20 {
            match svc.submit(SampleRequest {
                n: 4,
                steps: 40,
                seed: i,
                return_samples: false,
                ..Default::default()
            }) {
                Ok(rx) => receivers.push(rx),
                Err(resp) => {
                    assert_eq!(resp.kind, Some(FailureKind::QueueFull));
                    rejected += 1;
                }
            }
        }
        assert!(rejected > 0, "queue cap must reject under overload");
        for rx in receivers {
            let _ = rx.recv();
        }
        svc.shutdown();
    }

    #[test]
    fn plan_cache_shared_across_same_config_requests() {
        let svc = analytic_service(2, 16);
        let req = SampleRequest { n: 2, steps: 6, seed: 1, ..Default::default() };
        assert!(svc.sample_blocking(req.clone()).ok);
        // Same solver config, different seed: must hit the cached plan.
        assert!(svc.sample_blocking(SampleRequest { seed: 2, ..req.clone() }).ok);
        let m = svc.metrics_json();
        assert_eq!(m.get("plan_builds").unwrap().as_f64(), Some(1.0));
        assert_eq!(m.get("plan_hits").unwrap().as_f64(), Some(1.0));
        // A different config builds its own plan.
        assert!(svc.sample_blocking(SampleRequest { steps: 7, seed: 3, ..req }).ok);
        let m = svc.metrics_json();
        assert_eq!(m.get("plan_builds").unwrap().as_f64(), Some(2.0));
        assert_eq!(m.get("plan_hits").unwrap().as_f64(), Some(1.0));
        // Non-UniPC methods are plan-cached too (the whole zoo compiles):
        // the first dpmpp-2m request builds, the second hits.
        let baseline = SampleRequest {
            method: "dpmpp-2m".into(),
            unic: false,
            seed: 4,
            ..Default::default()
        };
        assert!(svc.sample_blocking(baseline.clone()).ok);
        assert!(svc.sample_blocking(SampleRequest { seed: 5, ..baseline }).ok);
        let m = svc.metrics_json();
        assert_eq!(m.get("plan_builds").unwrap().as_f64(), Some(3.0));
        assert_eq!(m.get("plan_hits").unwrap().as_f64(), Some(2.0));
        svc.shutdown();
    }

    #[test]
    fn batched_execution_matches_solo_and_counts_metrics() {
        // One worker with a generous linger window: rapid-fire same-config
        // submissions coalesce into a lockstep batched run; the serialized
        // first pass runs each request as a batch of one. Both paths must
        // produce bit-identical samples.
        let spec = DatasetSpec::Cifar10Like;
        let gm = Arc::new(dataset(spec));
        let classes = (0..spec.n_classes()).map(|c| spec.class_components(c)).collect();
        let cfg = ServerConfig {
            workers: 1,
            queue_cap: 64,
            batch_linger_us: 50_000,
            ..Default::default()
        };
        let svc = Service::start(
            cfg,
            ModelBackend::Analytic { gm, class_components: Arc::new(classes) },
        );
        let reqs: Vec<SampleRequest> = (0..6)
            .map(|i| SampleRequest { n: 2, steps: 5, seed: i, ..Default::default() })
            .collect();
        let solo: Vec<Vec<f64>> = reqs
            .iter()
            .map(|r| svc.sample_blocking(r.clone()).samples.unwrap())
            .collect();
        let rxs: Vec<_> = reqs.iter().map(|r| svc.submit(r.clone()).unwrap()).collect();
        let batched: Vec<Vec<f64>> = rxs
            .into_iter()
            .map(|rx| rx.recv().unwrap().samples.unwrap())
            .collect();
        assert_eq!(solo, batched, "batched execution must be bit-identical to solo");

        let m = svc.metrics_json();
        assert_eq!(m.get("completed").unwrap().as_f64(), Some(12.0));
        assert!(
            m.get("batched_runs").unwrap().as_f64().unwrap() >= 1.0,
            "concurrent same-config requests must coalesce: {m:?}"
        );
        assert!(
            m.get("workspace_reuses").unwrap().as_f64().unwrap() >= 1.0,
            "per-worker workspace must be reused across runs: {m:?}"
        );
        svc.shutdown();
    }

    #[test]
    fn methods_dispatch_through_service() {
        let svc = analytic_service(2, 16);
        for method in ["ddim", "dpmpp-2m", "dpmpp-3m", "unipc-2-bh1", "pndm", "deis-2"] {
            let r = svc.sample_blocking(SampleRequest {
                n: 1,
                steps: 6,
                method: method.into(),
                unic: false,
                seed: 1,
                ..Default::default()
            });
            assert!(r.ok, "{method}: {:?}", r.error);
            assert!(r.samples.unwrap().iter().all(|v| v.is_finite()), "{method}");
        }
        svc.shutdown();
    }

    #[test]
    fn plan_cache_lru_keeps_hot_entry_under_churn() {
        let sched = VpLinear::default();
        let build = || {
            let opts = SampleOptions::new(
                crate::solver::Method::parse("dpmpp-2m").unwrap(),
                5,
            );
            Arc::new(SamplePlan::build(&sched, &opts).unwrap())
        };
        let mut cache = PlanCache::new(4);
        cache.insert("hot".into(), build());
        for i in 0..20 {
            // Touch the hot entry between every churn insert: last-use LRU
            // must keep it while cold one-shot keys cycle through.
            assert!(cache.get("hot").is_some(), "hot plan evicted at churn {i}");
            cache.insert(format!("cold-{i}"), build());
            assert!(cache.len() <= 4, "cap exceeded at churn {i}");
        }
        assert!(cache.get("hot").is_some(), "hot plan must survive churn");
        assert!(cache.get("cold-0").is_none(), "oldest cold key must be evicted");
    }

    #[test]
    fn submit_after_shutdown_rejected_with_typed_response() {
        let svc = analytic_service(1, 4);
        svc.shutdown();
        let r = svc.submit(SampleRequest::default());
        match r {
            Err(resp) => {
                assert!(!resp.ok);
                assert_eq!(resp.kind, Some(FailureKind::BackendError));
            }
            Ok(_) => panic!("submit after shutdown must be rejected"),
        }
        // Shutdown is idempotent.
        svc.shutdown();
    }
}
