//! **End-to-end driver**: boots the full serving stack on the trained
//! model — PJRT executor → coordinator → TCP server — drives a Poisson
//! workload of batched sampling requests with mixed NFE budgets and
//! methods, reports latency/throughput, and cross-checks one request's
//! output against a directly-computed reference.
//!
//! Demonstrates: the production serving scenario the paper's NFE claims
//! translate into — admission control, the shared plan cache, lockstep
//! request batching, and per-request determinism under concurrent load.
//!
//!   make artifacts && cargo run --release --offline --example serve_e2e

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use unipc::analytic::datasets::{dataset, DatasetSpec};
use unipc::config::ServerConfig;
use unipc::coordinator::{ModelBackend, SampleRequest, Service};
use unipc::numerics::vandermonde::BFunction;
use unipc::rng::Rng;
use unipc::runtime::{EngineOptions, PjrtHandle, PjrtModel};
use unipc::sched::VpLinear;
use unipc::server::{run_load, Client, LoadConfig, Server};
use unipc::solver::{sample, Model, Prediction, SampleOptions};

fn main() -> anyhow::Result<()> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let have_artifacts = dir.join("manifest.json").exists() && dir.join("model.upw").exists();

    // 1. Backend.
    let (backend, pjrt) = if have_artifacts {
        let h = PjrtHandle::spawn(
            &dir,
            None,
            EngineOptions { max_batch: 64, batch_wait: Duration::from_micros(200) },
        )?;
        println!("backend: trained model via PJRT (dim {}, {} classes)", h.dim, h.n_classes);
        (ModelBackend::Pjrt(h.clone()), Some(h))
    } else {
        println!("backend: analytic (run `make artifacts` for the trained model)");
        let spec = DatasetSpec::Cifar10Like;
        let gm = Arc::new(dataset(spec));
        let classes = (0..spec.n_classes()).map(|c| spec.class_components(c)).collect();
        (ModelBackend::Analytic { gm, class_components: Arc::new(classes) }, None)
    };

    // 2. Service + server.
    let svc = Service::start(
        ServerConfig { workers: 4, queue_cap: 256, ..Default::default() },
        backend,
    );
    let server = Server::spawn(svc.clone(), "127.0.0.1:0")?;
    println!("server : {} (4 workers across {} shards)", server.addr, svc.shards());

    // 3. Correctness cross-check: one guided request through the full stack
    //    vs the same solve computed directly.
    let mut client = Client::connect(&server.addr.to_string())?;
    let req = SampleRequest {
        n: 4,
        steps: 8,
        method: "unipc-3".into(),
        unic: true,
        class: Some(2),
        guidance: Some(1.5),
        seed: 1234,
        return_samples: true,
        ..Default::default()
    };
    let resp = client.sample(&req)?;
    anyhow::ensure!(resp.ok, "request failed: {:?}", resp.error);
    println!(
        "check  : request ok, nfe={} queue={}us compute={}us",
        resp.nfe, resp.queue_us, resp.compute_us
    );
    if let Some(h) = &pjrt {
        let model = PjrtModel::new(h.clone()).with_class(2, Some(1.5));
        let sched = VpLinear::default();
        let x_t = Rng::seed_from(1234).normal_tensor(&[4, model.dim()]);
        let direct = sample(
            &model,
            &sched,
            &x_t,
            &SampleOptions::unipc(3, BFunction::Bh2, Prediction::Noise, 8),
        )
        .x;
        let got = resp.samples.as_ref().unwrap();
        let mut max_err = 0.0f64;
        for (a, b) in got.iter().zip(direct.data()) {
            max_err = max_err.max((a - b).abs());
        }
        anyhow::ensure!(max_err < 1e-5, "server output diverges from direct solve: {max_err}");
        println!("check  : server output == direct solve (max err {max_err:.2e})");
    }

    // 4. Mixed workload under Poisson load: three request classes.
    println!("\n== mixed Poisson workload ==");
    for (label, template) in [
        (
            "unipc-3 @ 8 NFE, n=4",
            SampleRequest {
                n: 4,
                steps: 8,
                method: "unipc-3".into(),
                unic: true,
                return_samples: false,
                ..Default::default()
            },
        ),
        (
            "unipc-2 guided @ 6 NFE, n=2",
            SampleRequest {
                n: 2,
                steps: 6,
                method: "unipc-2".into(),
                unic: true,
                class: Some(1),
                guidance: Some(2.0),
                return_samples: false,
                ..Default::default()
            },
        ),
        (
            "dpmpp-3m @ 10 NFE, n=4",
            SampleRequest {
                n: 4,
                steps: 10,
                method: "dpmpp-3m".into(),
                unic: false,
                return_samples: false,
                ..Default::default()
            },
        ),
    ] {
        let cfg = LoadConfig {
            rps: 12.0,
            total: 36,
            connections: 3,
            template,
            seed: 5,
            key_mix: 1,
            mix_guidance: None,
            plan_mix: 1,
        };
        let mut report = run_load(&server.addr.to_string(), &cfg)?;
        println!("{label:<32} {}", report.summary());
    }

    // 5. Batching effectiveness + service metrics.
    if let Some(h) = &pjrt {
        let s = h.stats()?;
        println!(
            "\npjrt   : {} calls, {:.2} mean rows/call, {} padded rows, hist {:?}",
            s.calls,
            s.mean_rows_per_call(),
            s.padded_rows,
            s.batch_hist
        );
    }
    println!("metrics: {}", svc.metrics_json().to_string());

    server.stop();
    svc.shutdown();
    if let Some(h) = pjrt {
        h.shutdown();
    }
    Ok(())
}
