//! Qualitative comparison: generate samples per method from the trained
//! model at very low NFE and report how close each population sits to the
//! true mixture — plus a per-sample "nearest mode" readout (the analog of
//! eyeballing which samples are crisp vs blurry).
//!
//! Demonstrates: the paper's Fig. 2/5/6 qualitative galleries, recast as
//! population-quality metrics the analytic substrate can score exactly.
//!
//!   make artifacts && cargo run --release --offline --example gallery

use std::path::Path;

use unipc::analytic::GaussianMixture;
use unipc::evalharness::{gen_samples, quality};
use unipc::json;
use unipc::numerics::vandermonde::BFunction;
use unipc::runtime::{EngineOptions, PjrtHandle, PjrtModel};
use unipc::sched::VpLinear;
use unipc::solver::{DynamicThresholding, Method, Prediction, SampleOptions};

fn load_mixture(dir: &Path) -> anyhow::Result<(GaussianMixture, usize)> {
    let v = json::parse(&std::fs::read_to_string(dir.join("mixture.json"))?)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let means: Vec<Vec<f64>> = v
        .get("means")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|row| row.as_arr().unwrap().iter().map(|x| x.as_f64().unwrap()).collect())
        .collect();
    let stds: Vec<f64> =
        v.get("stds").unwrap().as_arr().unwrap().iter().map(|x| x.as_f64().unwrap()).collect();
    let weights: Vec<f64> =
        v.get("weights").unwrap().as_arr().unwrap().iter().map(|x| x.as_f64().unwrap()).collect();
    let cpc = v.get("comps_per_class").unwrap().as_usize().unwrap();
    Ok((GaussianMixture::new(means, stds, weights), cpc))
}

fn main() -> anyhow::Result<()> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() || !dir.join("model.upw").exists() {
        println!("gallery: run `make artifacts` first");
        return Ok(());
    }
    let (gm, comps_per_class) = load_mixture(&dir)?;
    let handle = PjrtHandle::spawn(&dir, None, EngineOptions::default())?;
    let sched = VpLinear::default();
    let nfe = 7; // the Figure 2 budget
    let class = 4usize;

    println!("== gallery: trained model, class {class}, {nfe} NFE, CFG 2.0 ==\n");
    let methods: Vec<(&str, SampleOptions)> = vec![
        ("DDIM", SampleOptions::new(Method::Ddim { pred: Prediction::Noise }, nfe)),
        ("DEIS-2", SampleOptions::new(Method::Deis { order: 2 }, nfe)),
        ("DPM-Solver++(2M)", {
            let mut o = SampleOptions::new(Method::DpmSolverPp { order: 2 }, nfe);
            o.thresholding = Some(DynamicThresholding::clip(6.0));
            o
        }),
        ("UniPC-2 (ours)", {
            // Guided sampling uses order 2, data prediction and a
            // thresholding-clip (paper §3.4/§4.1: UniP-2 + UniC-2 for
            // guided); noise-pred high-order diverges under guidance.
            let mut o = SampleOptions::unipc(2, BFunction::Bh2, Prediction::Data, nfe);
            o.thresholding = Some(DynamicThresholding::clip(6.0));
            o
        }),
    ];

    for (label, opts) in &methods {
        let model = PjrtModel::new(handle.clone()).with_class(class, Some(2.0));
        let (samples, _) = gen_samples(&model, &sched, opts, 256, 99, 64);
        let (frechet, sw2) = quality(&gm, &samples, 99);

        // Per-sample nearest mixture component + whether it's in-class.
        let mut in_class = 0usize;
        let mut mean_dist = 0.0;
        for i in 0..samples.shape()[0] {
            let row = samples.row(i);
            let (mut best_k, mut best_d) = (0usize, f64::INFINITY);
            for (k, m) in gm.means.iter().enumerate() {
                let d: f64 = row.iter().zip(m).map(|(a, b)| (a - b) * (a - b)).sum();
                if d < best_d {
                    best_d = d;
                    best_k = k;
                }
            }
            if best_k / comps_per_class == class {
                in_class += 1;
            }
            mean_dist += best_d.sqrt();
        }
        mean_dist /= samples.shape()[0] as f64;
        println!(
            "{label:<20} frechet={frechet:8.4}  sw2={sw2:7.4}  in-class={:5.1}%  mode-dist={mean_dist:6.3}",
            100.0 * in_class as f64 / samples.shape()[0] as f64,
        );
    }
    println!("\nReading: lower frechet/sw2 and higher in-class% = crisper,");
    println!("better-guided samples (the paper's Fig. 2 visual comparison).");
    handle.shutdown();
    Ok(())
}
