//! Convergence study: how fast each solver family approaches the true ODE
//! solution on an analytic benchmark — the quantitative core of the paper's
//! claims, visualized as text tables.
//!
//! Demonstrates: the Fig. 3 (unconditional) / Fig. 4 (guided) error-vs-NFE
//! series, and the Fig. 4(c) empirical order-of-convergence slopes that back
//! Theorem 3.1 (UniC raises a p-th order sampler to order p + 1).
//!
//!   cargo run --release --offline --example convergence_study

use unipc::analytic::datasets::{dataset, DatasetSpec};
use unipc::analytic::GmmModel;
use unipc::evalharness::{RefErr, ResultTable};
use unipc::numerics::vandermonde::BFunction;
use unipc::sched::VpLinear;
use unipc::solver::unipc::CoeffVariant;
use unipc::solver::{Method, Prediction, SampleOptions};

fn main() {
    let gm = dataset(DatasetSpec::Cifar10Like);
    let sched = VpLinear::default();
    let model = GmmModel { gm: &gm, sched: &sched };
    let re = RefErr::new(&model, &sched, 16, 42, 1.0, 1e-3, 3000);

    let nfes = [5usize, 6, 8, 10, 15, 20];
    let mut table = ResultTable::new(
        "Convergence: l2 distance to the true ODE solution (cifar10-like)",
        &nfes,
    );
    let rows: Vec<(&str, Box<dyn Fn(usize) -> SampleOptions>)> = vec![
        (
            "DDIM (order 1)",
            Box::new(|s| SampleOptions::new(Method::Ddim { pred: Prediction::Noise }, s)),
        ),
        ("PNDM", Box::new(|s| SampleOptions::new(Method::Plms, s))),
        ("DEIS-3", Box::new(|s| SampleOptions::new(Method::Deis { order: 3 }, s))),
        (
            "DPM-Solver++(3M)",
            Box::new(|s| SampleOptions::new(Method::DpmSolverPp { order: 3 }, s)),
        ),
        (
            "UniP-3 (predictor only)",
            Box::new(|s| SampleOptions::new(Method::unip(3, BFunction::Bh2, Prediction::Noise), s)),
        ),
        (
            "UniPC-3",
            Box::new(|s| SampleOptions::unipc(3, BFunction::Bh2, Prediction::Noise, s)),
        ),
        (
            "UniPC_v-3",
            Box::new(|s| {
                SampleOptions::new(
                    Method::UniP {
                        order: 3,
                        variant: CoeffVariant::Varying,
                        pred: Prediction::Noise,
                        schedule: None,
                    },
                    s,
                )
                .with_unic(CoeffVariant::Varying, false)
            }),
        ),
    ];
    for (label, mk) in &rows {
        table.push(label, nfes.iter().map(|&n| re.err(&model, &sched, &mk(n))).collect());
    }
    println!("{}", table.render());

    println!("Reading: every column is one NFE budget; UniPC-3 should sit at");
    println!("the bottom of each, with the margin largest at 5-6 NFE — the");
    println!("paper's Figure 3 shape. Run `cargo bench` for the full grids.");
}
