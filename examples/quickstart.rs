//! Quickstart: sample from a diffusion model with UniPC in ~30 lines.
//!
//! Demonstrates: the paper's headline low-NFE setting — UniPC-3 with B₂(h)
//! at 10 NFE (the Table 1/2 configuration that reaches 3.87 FID on CIFAR10
//! in the paper) — driven through the public build→cache→execute sampling
//! API (`SamplePlan` resolution happens inside `solver::sample`).
//!
//!   cargo run --release --offline --example quickstart
//!
//! Uses the trained PJRT model when `make artifacts` has run, otherwise the
//! analytic mixture — the sampler API is identical.

use std::path::Path;

use unipc::analytic::datasets::{dataset, DatasetSpec};
use unipc::analytic::GmmModel;
use unipc::numerics::vandermonde::BFunction;
use unipc::rng::Rng;
use unipc::runtime::{EngineOptions, PjrtHandle, PjrtModel};
use unipc::sched::VpLinear;
use unipc::solver::{sample, Model, Prediction, SampleOptions};

fn main() -> anyhow::Result<()> {
    let sched = VpLinear::default();
    // 8 samples, 10 NFE, UniPC-3 with B₂ — the paper's headline setting.
    let opts = SampleOptions::unipc(3, BFunction::Bh2, Prediction::Noise, 10);

    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let gm = dataset(DatasetSpec::Cifar10Like);
    let (result, backend) = if dir.join("manifest.json").exists() && dir.join("model.upw").exists()
    {
        let handle = PjrtHandle::spawn(&dir, None, EngineOptions::default())?;
        let model = PjrtModel::new(handle.clone()).with_class(3, Some(1.5));
        let x_t = Rng::seed_from(7).normal_tensor(&[8, model.dim()]);
        let r = sample(&model, &sched, &x_t, &opts);
        handle.shutdown();
        (r, "trained model via PJRT (class 3, CFG 1.5)")
    } else {
        let model = GmmModel { gm: &gm, sched: &sched };
        let x_t = Rng::seed_from(7).normal_tensor(&[8, model.dim()]);
        (sample(&model, &sched, &x_t, &opts), "analytic mixture")
    };

    println!("backend : {backend}");
    println!("sampler : {} ({} NFE)", opts.id(), result.nfe);
    println!("samples : {:?} (first row)", &result.x.row(0)[..4.min(result.x.shape()[1])]);
    println!("rms     : {:.3}", result.x.rms());
    Ok(())
}
