//! Order-schedule search (paper §4.2 "Customizing order schedule"): exhausts
//! all monotone-start order schedules at a small NFE budget and reports the
//! best ones.
//!
//! Demonstrates: the experiment behind Table 4 — custom per-step order
//! schedules beating the fixed warm-up ramp at very low NFE — extended into
//! an actual search tool over the schedule space.
//!
//!   cargo run --release --offline --example schedule_search -- [--nfe 6]

use unipc::analytic::datasets::{dataset, DatasetSpec};
use unipc::analytic::GmmModel;
use unipc::cli::Args;
use unipc::evalharness::RefErr;
use unipc::numerics::vandermonde::BFunction;
use unipc::sched::VpLinear;
use unipc::solver::unipc::CoeffVariant;
use unipc::solver::{Method, Prediction, SampleOptions};

/// Enumerate schedules: s[0] = 1, each step can raise the order by at most
/// one (warm-up constraint), capped at `max_order`.
fn schedules(len: usize, max_order: usize) -> Vec<Vec<usize>> {
    let mut out = Vec::new();
    let mut cur = vec![1usize];
    fn rec(cur: &mut Vec<usize>, len: usize, max_order: usize, out: &mut Vec<Vec<usize>>) {
        if cur.len() == len {
            out.push(cur.clone());
            return;
        }
        let last = *cur.last().unwrap();
        let hi = (last + 1).min(max_order).min(cur.len() + 1);
        for next in 1..=hi {
            cur.push(next);
            rec(cur, len, max_order, out);
            cur.pop();
        }
    }
    rec(&mut cur, len, max_order, &mut out);
    out
}

fn main() {
    let (_, args) = Args::from_env();
    let nfe = args.get_usize("nfe", 6).unwrap_or(6);
    let max_order = args.get_usize("max-order", 4).unwrap_or(4);

    let gm = dataset(DatasetSpec::Cifar10Like);
    let sched = VpLinear::default();
    let model = GmmModel { gm: &gm, sched: &sched };
    let re = RefErr::new(&model, &sched, 16, 42, 1.0, 1e-3, 3000);

    let all = schedules(nfe, max_order);
    println!("searching {} schedules at NFE={nfe} (max order {max_order})", all.len());

    let mut scored: Vec<(f64, String)> = all
        .iter()
        .map(|schedule| {
            let opts = SampleOptions::new(
                Method::UniP {
                    order: *schedule.iter().max().unwrap(),
                    variant: CoeffVariant::Bh(BFunction::Bh1),
                    pred: Prediction::Noise,
                    schedule: Some(schedule.clone()),
                },
                nfe,
            )
            .with_unic(CoeffVariant::Bh(BFunction::Bh1), false);
            let err = re.err(&model, &sched, &opts);
            let label: String = schedule.iter().map(|o| o.to_string()).collect();
            (err, label)
        })
        .collect();
    scored.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());

    println!("\ntop 10 schedules:");
    for (err, label) in scored.iter().take(10) {
        println!("  {label:<12} l2={err:.5}");
    }
    println!("\nbottom 3 (the 'as high as possible' trap the paper warns about):");
    for (err, label) in scored.iter().rev().take(3) {
        println!("  {label:<12} l2={err:.5}");
    }

    // Default (ascending capped at 3) for comparison.
    let default: Vec<usize> = (1..=nfe).map(|i| i.min(3)).collect();
    let dl: String = default.iter().map(|o| o.to_string()).collect();
    let de = scored.iter().find(|(_, l)| l == &dl);
    if let Some((err, _)) = de {
        println!("\ndefault {dl}: l2={err:.5}");
    }
}
